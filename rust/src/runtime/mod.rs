//! TinyLM runtime: load and execute the AOT-compiled TinyLM artifacts.
//!
//! The AOT bridge's Rust half (DESIGN.md §4): `python/compile/aot.py` wrote
//! HLO text plus `params.bin`/`manifest.json`; this module parses the
//! manifest (with the in-repo JSON parser), loads the parameters, and
//! exposes typed prefill/decode calls. No Python anywhere near this path.
//!
//! Execution backend: a pure-Rust CPU interpreter of the TinyLM forward
//! pass (the architecture `python/compile/model.py` lowers: 4-layer RoPE
//! transformer, RMSNorm, GELU MLP, causal attention, paged-style KV cache
//! [L, B, Smax, H, D]). The build environment vendors no `xla`/PJRT crate
//! (DESIGN.md §2 offline-dependency substitutions), so the HLO files are
//! carried as artifacts-of-record while compute runs here. The manifest's
//! artifact entries still define which (batch, seq) shapes exist — calls
//! for unlisted batch sizes fail exactly as the compiled path did, keeping
//! `RealEngine`'s batch-padding logic honest.
//!
//! Compute is organized as a kernel layer ([`kernels`]): position-blocked
//! cache-tiled GEMM over whole [S, Dm] activation blocks in prefill,
//! RoPE sin/cos tables precomputed at load, flat [`kernels::Workspace`]
//! arenas pooled across calls, and scoped-thread parallelism over
//! independent batch rows / vocab tiles (`AIBRIX_RT_THREADS` override).
//! The pre-kernel scalar path is retained in [`reference`] as the golden
//! model and the perf baseline `benches/runtime_throughput.rs` records.
//!
//! Numerical contract (rust/tests/runtime_e2e.rs): greedy decode is
//! deterministic, batch rows are independent, thread count never changes
//! bits, and the KV-cache decode path is bit-exact with re-prefill —
//! prefill and decode share [`TinyLmRuntime::forward_row`] and the
//! ascending-k kernels, so the last property holds exactly.
//!
//! Precision tiers: the default [`Precision::F32`] path keeps the
//! bit-exact contract above against [`reference`]. [`Precision::Int8`]
//! (`AIBRIX_RT_PRECISION=int8`, `aibrix serve --precision int8`, or
//! [`TinyLmRuntime::set_precision`]) stores every weight-GEMM operand as
//! per-output-channel symmetric int8 quantized once at load
//! ([`kernels::QuantMat`]), cutting weight bytes moved per matmul 4x. It
//! carries a relaxed-exactness contract instead — documented error bounds
//! vs the f32 kernels plus a greedy top-1 agreement check — but every
//! within-mode property (determinism, row independence, thread
//! invariance, decode == re-prefill, seeded prefill) still holds
//! bit-exactly, because the int8 kernels keep the same ascending-k order.

pub mod kernels;
mod reference;

use std::collections::{BTreeMap, BTreeSet};
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::{parse, Json};
use crate::util::err::{Error, Result};
use kernels::{QuantMat, RawSlice, RopeTables, Workspace};

/// Rotary-embedding frequency base (matches `python/compile/model.py`).
const ROPE_BASE: f32 = 10_000.0;

/// Below this vocab size, splitting a single logits row across threads
/// costs more in spawns than the dots it saves.
const VOCAB_PAR_MIN: usize = 1024;

/// Numeric execution tier for the runtime's weight GEMMs.
///
/// `F32` is the bit-exact contract path (kernel == scalar reference, bit
/// for bit). `Int8` runs per-output-channel symmetric int8 weights
/// (quantized once at load; f32 activations, f32 accumulation) — ~4x less
/// weight traffic per matmul in exchange for a relaxed-exactness test
/// contract (bounded error vs f32, greedy top-1 agreement; BENCHMARKS.md).
/// Within either mode all determinism properties hold bit-exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Precision::F32),
            "int8" | "i8" => Ok(Precision::Int8),
            other => {
                Err(Error::msg(format!("unknown precision {other:?} (expected f32 or int8)")))
            }
        }
    }

    /// The `AIBRIX_RT_PRECISION` override (unset -> f32). An unparsable
    /// value warns and falls back to f32 — a library load must not panic
    /// on a stray env var; the CLI `--precision` flag is the loud path.
    pub fn from_env() -> Precision {
        match std::env::var("AIBRIX_RT_PRECISION") {
            Ok(s) => Precision::parse(&s).unwrap_or_else(|e| {
                eprintln!("AIBRIX_RT_PRECISION: {e}; using f32");
                Precision::F32
            }),
            Err(_) => Precision::F32,
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Precision, String> {
        Precision::parse(s).map_err(|e| e.to_string())
    }
}

/// Dense row-major f32 tensor (parameters, KV caches).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Host-side KV tensor handed back to the decode loop ([L, B, Smax, H, D]).
pub type DeviceTensor = Tensor;

/// Model hyper-parameters from the manifest.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub page_size: usize,
}

impl ModelCfg {
    /// Bytes of KV cache per token under this runtime's layout: one K and
    /// one V row of `d_model` f32s per layer. The single source of truth
    /// for sizing KV-pool shards (serve, serve_e2e, kvpool bench).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (self.n_layers * self.d_model * 2 * std::mem::size_of::<f32>()) as u64
    }
}

#[derive(Debug, Clone)]
struct ParamEntry {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    numel: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub seq: usize,
    pub file: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub cfg: ModelCfg,
    params: Vec<ParamEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::msg(format!("reading manifest in {dir:?} (run `make artifacts`): {e}"))
        })?;
        let j = parse(&text).map_err(|e| Error::msg(format!("manifest.json: {e}")))?;
        let c = &j["config"];
        let need = |v: &Json, k: &str| -> Result<usize> {
            v[k].as_usize().ok_or_else(|| Error::msg(format!("manifest config missing {k}")))
        };
        let cfg = ModelCfg {
            vocab: need(c, "vocab")?,
            d_model: need(c, "d_model")?,
            n_layers: need(c, "n_layers")?,
            n_heads: need(c, "n_heads")?,
            head_dim: need(c, "head_dim")?,
            max_seq: need(c, "max_seq")?,
            page_size: need(c, "page_size")?,
        };
        let params = j["params"]
            .as_arr()
            .ok_or_else(|| Error::msg("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p["name"].as_str().unwrap_or_default().to_string(),
                    shape: p["shape"]
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p["offset"].as_usize().ok_or_else(|| Error::msg("offset"))?,
                    numel: p["numel"].as_usize().ok_or_else(|| Error::msg("numel"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j["artifacts"]
            .as_arr()
            .ok_or_else(|| Error::msg("manifest missing artifacts"))?
            .iter()
            .map(|a| ArtifactEntry {
                name: a["name"].as_str().unwrap_or_default().to_string(),
                kind: a["kind"].as_str().unwrap_or_default().to_string(),
                batch: a["batch"].as_usize().unwrap_or(0),
                seq: a["seq"].as_usize().unwrap_or(0),
                file: a["file"].as_str().unwrap_or_default().to_string(),
            })
            .collect();
        Ok(Manifest { cfg, params, artifacts, dir: dir.to_path_buf() })
    }

    /// Read params.bin into per-parameter f32 tensors (manifest order).
    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        let mut f = std::fs::File::open(self.dir.join("params.bin"))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        let total: usize = self.params.iter().map(|p| p.numel).sum();
        if bytes.len() != total * 4 {
            return Err(Error::msg(format!(
                "params.bin is {} bytes, manifest wants {}",
                bytes.len(),
                total * 4
            )));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        self.params
            .iter()
            .map(|p| {
                let shape_elems: usize = p.shape.iter().product();
                if p.offset + p.numel > floats.len() || shape_elems != p.numel {
                    return Err(Error::msg(format!("param {} malformed or out of bounds", p.name)));
                }
                Ok(Tensor {
                    dims: p.shape.clone(),
                    data: floats[p.offset..p.offset + p.numel].to_vec(),
                })
            })
            .collect()
    }

    /// Name of the i-th parameter (manifest order).
    fn param_name(&self, i: usize) -> &str {
        &self.params[i].name
    }
}

/// Output of one full prefill call (logits for every position).
pub struct PrefillOut {
    /// Logits for every position: [B][S][V] flattened per row.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// KV caches carried between calls by the decode loop.
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl PrefillOut {
    /// Logits row for batch `b` at position `pos`.
    pub fn logits_at(&self, b: usize, pos: usize) -> &[f32] {
        let start = (b * self.seq + pos) * self.vocab;
        &self.logits[start..start + self.vocab]
    }

    pub fn argmax_at(&self, b: usize, pos: usize) -> u32 {
        argmax(self.logits_at(b, pos))
    }
}

/// Output of [`TinyLmRuntime::prefill_last`]: logits for one selected
/// position per row only ([B][V]) — the positions-mask fast path `generate`
/// uses, skipping the full-vocab projection at every other prefill
/// position.
pub struct PrefillLastOut {
    /// [B][V] logits at each row's selected position.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub vocab: usize,
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl PrefillLastOut {
    pub fn logits_of(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn argmax_of(&self, b: usize) -> u32 {
        argmax(self.logits_of(b))
    }
}

/// A fetched KV prefix to install before a seeded prefill: `len` cached
/// positions (0 = cold row, the default) and the `[n_layers, len, d_model]`
/// K/V slabs in the layout `kvcache::blocks::assemble_prefix` produces.
/// Because the slabs were computed by the same bit-exact kernels over the
/// same token prefix at the same absolute positions, installing them and
/// computing only the suffix reproduces a cold prefill bit for bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeededPrefix<'a> {
    pub len: usize,
    pub k: &'a [f32],
    pub v: &'a [f32],
}

/// An int8-resident fetched KV prefix ([`SeededPrefix`]'s quantized twin,
/// produced by `kvcache::blocks::assemble_prefix_stored` when the pool
/// stores int8): `[n_layers, len, d_model]` i8 slabs plus one symmetric
/// scale per (layer, position) row. The suffix attends *directly* over
/// these bytes (`kernels::attend_one_i8`) while the same bits are
/// dequantize-installed into the f32 cache for later decode steps —
/// bit-identical either way, because both use the dequantize-first
/// `f32::from(q) * scale` formula.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantSeededPrefix<'a> {
    pub len: usize,
    pub k: &'a [i8],
    pub v: &'a [i8],
    /// `[n_layers, len]` per-row K scales.
    pub k_scales: &'a [f32],
    /// `[n_layers, len]` per-row V scales.
    pub v_scales: &'a [f32],
}

/// Output of one decode step.
pub struct DecodeOut {
    /// [B][V] logits.
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl DecodeOut {
    pub fn logits_of(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn argmax_of(&self, b: usize) -> u32 {
        argmax(self.logits_of(b))
    }
}

/// One row's slice of an iteration-level scheduler step
/// ([`TinyLmRuntime::prefill_chunk`]): compute positions
/// `s0..s0+tokens.len()` of cache row `row`. A decode step is the
/// degenerate chunk (`s0 = pos`, one token); a chunked prefill is a
/// sequence of these over the prompt. Both ride the same
/// [`TinyLmRuntime::forward_row`] body, so any chunking of a prompt is
/// bit-identical to the one-shot prefill (the decode == re-prefill
/// contract, generalized to arbitrary split points).
#[derive(Debug, Clone, Copy)]
pub struct RowChunk<'a> {
    /// Cache row this chunk occupies (rows are independent).
    pub row: usize,
    /// Absolute position of `tokens[0]` in the row's sequence.
    pub s0: usize,
    /// Token ids occupying positions `s0..s0+len` (embedded + forwarded).
    pub tokens: &'a [i32],
    /// Fetched KV prefix to install first (requires `s0 == seed.len`):
    /// the pool-seeded fast path for the chunk that resumes a row.
    pub seed: Option<SeededPrefix<'a>>,
    /// Int8-resident fetched prefix (requires `s0 == qseed.len`; mutually
    /// exclusive with `seed`): the suffix attends directly over the pool's
    /// i8 bytes and the dequantized expansion is installed for decode.
    pub qseed: Option<QuantSeededPrefix<'a>>,
    /// Project logits at this chunk's last position (the scheduler
    /// samples from them). Mid-prompt prefill chunks skip the vocab
    /// projection entirely.
    pub emit_logits: bool,
    /// Telemetry attribution: true for single-token decode steps, false
    /// for prefill chunks (drives the prefill/decode counter split).
    pub decode: bool,
}

/// Output of one [`TinyLmRuntime::prefill_chunk`] iteration.
pub struct ChunkOut {
    /// [B][V] logits; only rows whose chunk set `emit_logits` are
    /// written (others stay zero).
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl ChunkOut {
    pub fn logits_of(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn argmax_of(&self, b: usize) -> u32 {
        argmax(self.logits_of(b))
    }
}

/// One weight GEMM of the forward pass, dispatched to the active tier:
/// int8 when the quantized twin is present, else the bit-exact f32 kernel.
/// `panel` is the workspace's dequantization scratch (unused on f32).
#[allow(clippy::too_many_arguments)]
fn matmul(
    x: &[f32],
    w: &Tensor,
    q: Option<&QuantMat>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    panel: &mut Vec<f32>,
) {
    match q {
        Some(qm) => kernels::gemm_i8(x, qm, m, k, n, out, panel),
        None => kernels::gemm(x, &w.data, m, k, n, out),
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

// ------------------------------------------------------------ parameters

struct LayerParams {
    ln1: Tensor,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ln2: Tensor,
    w_in: Tensor,
    w_out: Tensor,
}

struct TinyLmParams {
    embed: Tensor, // [V, Dm]
    layers: Vec<LayerParams>,
    ln_f: Tensor, // [Dm]
    d_ff: usize,
}

impl TinyLmParams {
    fn from_manifest(manifest: &Manifest, tensors: Vec<Tensor>) -> Result<TinyLmParams> {
        let mut by_name: BTreeMap<String, Tensor> = BTreeMap::new();
        for (i, t) in tensors.into_iter().enumerate() {
            by_name.insert(manifest.param_name(i).to_string(), t);
        }
        let mut take = |name: &str| -> Result<Tensor> {
            by_name.remove(name).ok_or_else(|| Error::msg(format!("manifest missing param {name}")))
        };
        let embed = take("embed")?;
        let mut layers = Vec::new();
        for i in 0..manifest.cfg.n_layers {
            layers.push(LayerParams {
                ln1: take(&format!("l{i}.ln1"))?,
                wq: take(&format!("l{i}.wq"))?,
                wk: take(&format!("l{i}.wk"))?,
                wv: take(&format!("l{i}.wv"))?,
                wo: take(&format!("l{i}.wo"))?,
                ln2: take(&format!("l{i}.ln2"))?,
                w_in: take(&format!("l{i}.w_in"))?,
                w_out: take(&format!("l{i}.w_out"))?,
            });
        }
        let ln_f = take("ln_f")?;
        let d_ff = layers
            .first()
            .and_then(|l| l.w_in.dims.get(1).copied())
            .ok_or_else(|| Error::msg("cannot infer d_ff from l0.w_in"))?;
        Ok(TinyLmParams { embed, layers, ln_f, d_ff })
    }
}

/// Int8 twins of one layer's GEMM operands (column-scaled, [k, n]).
struct QuantLayer {
    wq: QuantMat,
    wk: QuantMat,
    wv: QuantMat,
    wo: QuantMat,
    w_in: QuantMat,
    w_out: QuantMat,
}

/// Per-output-channel symmetric int8 copies of every weight-GEMM operand,
/// built once when the runtime enters [`Precision::Int8`]: layer matrices
/// column-quantized (one scale per output column), the tied embedding
/// row-quantized (one scale per vocab row — the logits projection's output
/// channel). The f32 embedding stays resident for exact embedding lookups;
/// RMSNorm gains and the attention path (pure activation math) are not
/// quantized.
struct TinyLmQuantParams {
    embed: QuantMat,
    layers: Vec<QuantLayer>,
}

impl TinyLmQuantParams {
    fn from_params(p: &TinyLmParams, cfg: &ModelCfg) -> TinyLmQuantParams {
        let (dm, dff) = (cfg.d_model, p.d_ff);
        TinyLmQuantParams {
            embed: kernels::quantize_rows(&p.embed.data, cfg.vocab, dm),
            layers: p
                .layers
                .iter()
                .map(|l| QuantLayer {
                    wq: kernels::quantize_cols(&l.wq.data, dm, dm),
                    wk: kernels::quantize_cols(&l.wk.data, dm, dm),
                    wv: kernels::quantize_cols(&l.wv.data, dm, dm),
                    wo: kernels::quantize_cols(&l.wo.data, dm, dm),
                    w_in: kernels::quantize_cols(&l.w_in.data, dm, dff),
                    w_out: kernels::quantize_cols(&l.w_out.data, dff, dm),
                })
                .collect(),
        }
    }
}

// ------------------------------------------------------------- telemetry

/// Cumulative hot-path counters (atomics: prefill/decode take `&self` and
/// may be read from other threads via [`TinyLmRuntime::stats`]).
#[derive(Debug, Default)]
struct RtCounters {
    prefill_calls: AtomicU64,
    prefill_tokens: AtomicU64,
    prefill_us: AtomicU64,
    decode_calls: AtomicU64,
    decode_tokens: AtomicU64,
    decode_us: AtomicU64,
    seeded_prefill_rows: AtomicU64,
    seeded_prefill_tokens: AtomicU64,
    quant_gemm_calls: AtomicU64,
    quant_bytes_saved: AtomicU64,
}

/// Snapshot of runtime telemetry — the base quantities the BENCH pipeline
/// (BENCHMARKS.md) and the serving layers report throughput from.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RtStats {
    pub prefill_calls: u64,
    /// Computed prefill positions (active rows x padded seq).
    pub prefill_tokens: u64,
    pub prefill_us: u64,
    pub decode_calls: u64,
    /// Decoded tokens (active rows x steps).
    pub decode_tokens: u64,
    pub decode_us: u64,
    /// Rows whose prefill was seeded from the distributed KV pool.
    pub seeded_prefill_rows: u64,
    /// Prefill positions installed from fetched KV instead of computed —
    /// the compute the pool saved this runtime.
    pub seeded_prefill_tokens: u64,
    /// Weight GEMMs + vocab projections served by the int8 tier (0 on the
    /// f32 path).
    pub quant_gemm_calls: u64,
    /// Weight bytes those calls did not stream versus f32 storage (3 of
    /// every 4 bytes per weight element) — the bandwidth the int8 tier
    /// saved this runtime.
    pub quant_bytes_saved: u64,
}

impl RtStats {
    pub fn prefill_tokens_per_s(&self) -> f64 {
        if self.prefill_us == 0 {
            return 0.0;
        }
        self.prefill_tokens as f64 / (self.prefill_us as f64 / 1e6)
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_us == 0 {
            return 0.0;
        }
        self.decode_tokens as f64 / (self.decode_us as f64 / 1e6)
    }
}

// --------------------------------------------------------------- runtime

/// The loaded model: parameters + the artifact shape table + the kernel
/// layer's shared state (RoPE tables, workspace pools, thread budget).
pub struct TinyLmRuntime {
    pub cfg: ModelCfg,
    params: TinyLmParams,
    /// batch -> prefill sequence capacity, from the manifest's artifacts.
    prefill: BTreeMap<usize, usize>,
    /// Decode batch sizes with a compiled artifact.
    decode: BTreeSet<usize>,
    /// Precomputed RoPE sin/cos tables [max_seq, head_dim/2].
    rope: RopeTables,
    /// Scoped-thread worker budget (AIBRIX_RT_THREADS override at load).
    threads: usize,
    /// Active numeric tier ([`Precision::Int8`] requires `qparams`).
    precision: Precision,
    /// Int8 weights + per-channel scales, quantized at load when the
    /// precision mode asks for them (or lazily by `set_precision`).
    qparams: Option<TinyLmQuantParams>,
    /// Reusable per-worker scratch arenas (leased, never freed).
    ws_pool: Mutex<Vec<Workspace>>,
    /// Reusable flat residual buffers ([B, S, Dm] per prefill call).
    buf_pool: Mutex<Vec<Vec<f32>>>,
    counters: RtCounters,
}

/// Spec for an artifact-free, randomly-initialized runtime — benches,
/// proptests and `perf_probe` use this to exercise the kernel layer
/// without `make artifacts`.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub cfg: ModelCfg,
    pub d_ff: usize,
    /// (batch, seq) prefill shapes.
    pub prefill: Vec<(usize, usize)>,
    /// Decode batch sizes.
    pub decode: Vec<usize>,
    pub seed: u64,
}

impl SyntheticSpec {
    /// The 2-layer vocab-16 toy model the unit tests run on.
    pub fn tiny() -> SyntheticSpec {
        SyntheticSpec {
            cfg: ModelCfg {
                vocab: 16,
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                head_dim: 4,
                max_seq: 12,
                page_size: 4,
            },
            d_ff: 16,
            prefill: vec![(1, 8), (2, 8)],
            decode: vec![1, 2],
            seed: 7,
        }
    }
}

impl TinyLmRuntime {
    /// Load the manifest + parameters in `dir`.
    pub fn load(dir: &Path) -> Result<TinyLmRuntime> {
        let manifest = Manifest::load(dir)?;
        let tensors = manifest.load_params()?;
        let params = TinyLmParams::from_manifest(&manifest, tensors)?;

        let mut prefill = BTreeMap::new();
        let mut decode = BTreeSet::new();
        for a in &manifest.artifacts {
            match a.kind.as_str() {
                "prefill" => {
                    if a.seq == 0 || a.seq > manifest.cfg.max_seq {
                        return Err(Error::msg(format!(
                            "prefill artifact {} has seq {} outside (0, max_seq {}]",
                            a.name, a.seq, manifest.cfg.max_seq
                        )));
                    }
                    prefill.insert(a.batch, a.seq);
                }
                "decode" => {
                    decode.insert(a.batch);
                }
                k => return Err(Error::msg(format!("unknown artifact kind {k}"))),
            }
        }
        if prefill.is_empty() || decode.is_empty() {
            return Err(Error::msg(format!(
                "artifacts incomplete: {} prefill, {} decode",
                prefill.len(),
                decode.len()
            )));
        }
        Ok(Self::assemble(manifest.cfg, params, prefill, decode))
    }

    /// Build a runtime with random parameters (no artifacts on disk).
    pub fn synthetic(spec: &SyntheticSpec) -> TinyLmRuntime {
        let cfg = spec.cfg.clone();
        assert_eq!(cfg.d_model, cfg.n_heads * cfg.head_dim, "d_model != n_heads*head_dim");
        assert!(
            spec.prefill.iter().all(|&(_, s)| s > 0 && s <= cfg.max_seq),
            "prefill seq outside (0, max_seq]"
        );
        let mut rng = crate::util::Rng::new(spec.seed);
        let mut mk = |dims: Vec<usize>, norm: bool| {
            let n: usize = dims.iter().product();
            let fan_in = dims[0] as f64;
            let data: Vec<f32> = (0..n)
                .map(|_| if norm { 1.0 } else { (rng.normal() / fan_in.sqrt()) as f32 })
                .collect();
            Tensor { dims, data }
        };
        let (dm, dff) = (cfg.d_model, spec.d_ff);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                ln1: mk(vec![dm], true),
                wq: mk(vec![dm, dm], false),
                wk: mk(vec![dm, dm], false),
                wv: mk(vec![dm, dm], false),
                wo: mk(vec![dm, dm], false),
                ln2: mk(vec![dm], true),
                w_in: mk(vec![dm, dff], false),
                w_out: mk(vec![dff, dm], false),
            })
            .collect();
        let params = TinyLmParams {
            embed: mk(vec![cfg.vocab, dm], false),
            layers,
            ln_f: mk(vec![dm], true),
            d_ff: dff,
        };
        Self::assemble(
            cfg,
            params,
            spec.prefill.iter().copied().collect(),
            spec.decode.iter().copied().collect(),
        )
    }

    fn assemble(
        cfg: ModelCfg,
        params: TinyLmParams,
        prefill: BTreeMap<usize, usize>,
        decode: BTreeSet<usize>,
    ) -> TinyLmRuntime {
        let rope = RopeTables::new(cfg.max_seq, cfg.head_dim, ROPE_BASE);
        let mut rt = TinyLmRuntime {
            cfg,
            params,
            prefill,
            decode,
            rope,
            threads: kernels::default_threads(),
            precision: Precision::F32,
            qparams: None,
            ws_pool: Mutex::new(Vec::new()),
            buf_pool: Mutex::new(Vec::new()),
            counters: RtCounters::default(),
        };
        // Quantize at load when the environment asks for the int8 tier.
        rt.set_precision(Precision::from_env());
        rt
    }

    /// Available prefill batch sizes.
    pub fn prefill_batches(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    /// Available decode batch sizes.
    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.iter().copied().collect()
    }

    /// Prefill sequence capacity for batch `b`.
    pub fn prefill_seq(&self, batch: usize) -> Option<usize> {
        self.prefill.get(&batch).copied()
    }

    /// Current worker-thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Override the worker-thread budget (tests / benches; `load` and
    /// `synthetic` default to `AIBRIX_RT_THREADS` or host parallelism).
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    /// Active numeric tier.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Switch precision tiers. Entering [`Precision::Int8`] quantizes the
    /// weights on first use (per-output-channel symmetric; the f32
    /// parameters stay resident, so switching back to `F32` restores the
    /// bit-exact path unchanged). `load` and `synthetic` default to the
    /// `AIBRIX_RT_PRECISION` env override, else f32.
    pub fn set_precision(&mut self, p: Precision) {
        if p == Precision::Int8 && self.qparams.is_none() {
            self.qparams = Some(TinyLmQuantParams::from_params(&self.params, &self.cfg));
        }
        self.precision = p;
    }

    /// The int8 parameter set iff the int8 tier is active.
    fn quant_params(&self) -> Option<&TinyLmQuantParams> {
        match self.precision {
            Precision::Int8 => self.qparams.as_ref(),
            Precision::F32 => None,
        }
    }

    /// Telemetry snapshot (cumulative since load / last reset).
    pub fn stats(&self) -> RtStats {
        let c = &self.counters;
        RtStats {
            prefill_calls: c.prefill_calls.load(Ordering::Relaxed),
            prefill_tokens: c.prefill_tokens.load(Ordering::Relaxed),
            prefill_us: c.prefill_us.load(Ordering::Relaxed),
            decode_calls: c.decode_calls.load(Ordering::Relaxed),
            decode_tokens: c.decode_tokens.load(Ordering::Relaxed),
            decode_us: c.decode_us.load(Ordering::Relaxed),
            seeded_prefill_rows: c.seeded_prefill_rows.load(Ordering::Relaxed),
            seeded_prefill_tokens: c.seeded_prefill_tokens.load(Ordering::Relaxed),
            quant_gemm_calls: c.quant_gemm_calls.load(Ordering::Relaxed),
            quant_bytes_saved: c.quant_bytes_saved.load(Ordering::Relaxed),
        }
    }

    pub fn reset_stats(&self) {
        let c = &self.counters;
        for a in [
            &c.prefill_calls,
            &c.prefill_tokens,
            &c.prefill_us,
            &c.decode_calls,
            &c.decode_tokens,
            &c.decode_us,
            &c.seeded_prefill_rows,
            &c.seeded_prefill_tokens,
            &c.quant_gemm_calls,
            &c.quant_bytes_saved,
        ] {
            a.store(0, Ordering::Relaxed);
        }
    }

    /// Deterministic quant-telemetry bump for one prefill/decode call:
    /// `rows` forward_row rows ran every layer's 6 weight GEMMs through
    /// the int8 tier and `logits_jobs` vocab projections used the int8
    /// embedding; bytes saved counts 3 of every 4 bytes per weight element
    /// those calls would have streamed as f32. Computed centrally (not in
    /// the workers) so the numbers are thread-count invariant.
    fn bump_quant_counters(&self, rows: u64, logits_jobs: u64) {
        if self.quant_params().is_none() || (rows == 0 && logits_jobs == 0) {
            return;
        }
        let l = self.cfg.n_layers as u64;
        let (dm, v) = (self.cfg.d_model as u64, self.cfg.vocab as u64);
        let dff = self.params.d_ff as u64;
        self.counters.quant_gemm_calls.fetch_add(rows * l * 6 + logits_jobs, Ordering::Relaxed);
        let layer_w = 4 * dm * dm + 2 * dm * dff;
        self.counters
            .quant_bytes_saved
            .fetch_add(rows * l * 3 * layer_w + logits_jobs * 3 * v * dm, Ordering::Relaxed);
    }

    // ------------------------------------------------------ arena pools

    fn lease_ws(&self) -> Workspace {
        self.ws_pool.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
    }

    fn return_ws(&self, ws: Workspace) {
        if let Ok(mut p) = self.ws_pool.lock() {
            if p.len() < 64 {
                p.push(ws);
            }
        }
    }

    /// Lease a flat buffer resized to exactly `n` (contents unspecified;
    /// callers fully overwrite every region they later read).
    fn lease_buf(&self, n: usize) -> Vec<f32> {
        let mut b = self.buf_pool.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default();
        b.resize(n, 0.0);
        b
    }

    fn return_buf(&self, b: Vec<f32>) {
        if let Ok(mut p) = self.buf_pool.lock() {
            if p.len() < 16 {
                p.push(b);
            }
        }
    }

    fn kv_index(&self, layer: usize, batch: usize, b: usize, pos: usize) -> usize {
        ((layer * batch + b) * self.cfg.max_seq + pos) * self.cfg.n_heads * self.cfg.head_dim
    }

    // ------------------------------------------------------ forward core

    /// Run every transformer layer for `s_len` consecutive positions of
    /// cache row `b`, starting at absolute position `s0`. `x` holds the
    /// [s_len, Dm] residual rows (token embeddings on entry, final
    /// pre-norm hidden states on exit); K/V rows are written into the
    /// caches at positions s0..s0+s_len and attention covers cache
    /// positions 0..=pos for each query. Prefill calls this with
    /// (s0=0, s_len=S); decode with (s0=p, s_len=1) — one shared,
    /// bit-exact path.
    #[allow(clippy::too_many_arguments)]
    // lint:hot_path
    fn forward_row(
        &self,
        batch: usize,
        b: usize,
        s0: usize,
        s_len: usize,
        x: &mut [f32],
        k_raw: &RawSlice<'_>,
        v_raw: &RawSlice<'_>,
        qseed: Option<QuantSeededPrefix<'_>>,
        ws: &mut Workspace,
    ) {
        let cfg = &self.cfg;
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        let d_ff = self.params.d_ff;
        let quant = self.quant_params();
        ws.ensure(s_len, dm, d_ff, quant.is_some());
        for (layer, lp) in self.params.layers.iter().enumerate() {
            // Int8 twins of this layer's GEMM operands (None on the f32
            // contract path).
            let ql = quant.map(|q| &q.layers[layer]);
            let row_base = (layer * batch + b) * cfg.max_seq * dm;
            for s in 0..s_len {
                kernels::rms_norm(
                    &x[s * dm..(s + 1) * dm],
                    &lp.ln1.data,
                    &mut ws.xn[s * dm..(s + 1) * dm],
                );
            }
            let xn = &ws.xn[..s_len * dm];
            let q_out = &mut ws.q[..s_len * dm];
            matmul(xn, &lp.wq, ql.map(|q| &q.wq), s_len, dm, dm, q_out, &mut ws.wdq);
            {
                // K/V projections land straight in this row's cache slab —
                // positions are contiguous for a fixed (layer, row).
                // SAFETY: worker `b` is the only thread touching the
                // (layer, b) slabs of either cache.
                let k_dst = unsafe { k_raw.range_mut(row_base + s0 * dm, s_len * dm) };
                matmul(xn, &lp.wk, ql.map(|q| &q.wk), s_len, dm, dm, k_dst, &mut ws.wdq);
                // SAFETY: same exclusivity as k_dst above — worker `b` owns
                // the (layer, b) V slab, and this range doesn't overlap it.
                let v_dst = unsafe { v_raw.range_mut(row_base + s0 * dm, s_len * dm) };
                matmul(xn, &lp.wv, ql.map(|q| &q.wv), s_len, dm, dm, v_dst, &mut ws.wdq);
                for s in 0..s_len {
                    let pos = s0 + s;
                    for head in 0..h {
                        let o = s * dm + head * hd;
                        self.rope.apply(&mut ws.q[o..o + hd], pos);
                        self.rope.apply(&mut k_dst[o..o + hd], pos);
                    }
                }
            }
            {
                // Attention reads the slabs written above (same thread; the
                // mutable borrows ended with the previous block).
                let seen = (s0 + s_len) * dm;
                // SAFETY: shared read of row b's slab only.
                let k_row = unsafe { k_raw.range(row_base, seen) };
                // SAFETY: shared read of row b's V slab, written above on
                // this same thread (the mutable borrow has ended).
                let v_row = unsafe { v_raw.range(row_base, seen) };
                match qseed {
                    // Int8-seeded resume: the prefix positions 0..len are
                    // attended straight from the pool's i8 bytes (this
                    // layer's [len, dm] slice of the seed slabs), the
                    // freshly computed tail from the f32 cache —
                    // bit-identical to attending over the dequantized
                    // expansion installed above.
                    Some(qs) if qs.len > 0 => {
                        let side = qs.len * dm;
                        let kq = &qs.k[layer * side..(layer + 1) * side];
                        let vq = &qs.v[layer * side..(layer + 1) * side];
                        let ks = &qs.k_scales[layer * qs.len..(layer + 1) * qs.len];
                        let vs = &qs.v_scales[layer * qs.len..(layer + 1) * qs.len];
                        for s in 0..s_len {
                            let pos = s0 + s;
                            for head in 0..h {
                                let o = s * dm + head * hd;
                                kernels::attend_one_i8(
                                    &ws.q[o..o + hd],
                                    kq,
                                    ks,
                                    vq,
                                    vs,
                                    qs.len,
                                    k_row,
                                    v_row,
                                    pos + 1,
                                    head,
                                    h,
                                    &mut ws.scores,
                                    &mut ws.attn[o..o + hd],
                                );
                            }
                        }
                    }
                    _ => {
                        for s in 0..s_len {
                            let pos = s0 + s;
                            for head in 0..h {
                                let o = s * dm + head * hd;
                                kernels::attend_one(
                                    &ws.q[o..o + hd],
                                    k_row,
                                    v_row,
                                    pos + 1,
                                    head,
                                    h,
                                    &mut ws.scores,
                                    &mut ws.attn[o..o + hd],
                                );
                            }
                        }
                    }
                }
            }
            {
                let attn = &ws.attn[..s_len * dm];
                let proj = &mut ws.proj[..s_len * dm];
                matmul(attn, &lp.wo, ql.map(|q| &q.wo), s_len, dm, dm, proj, &mut ws.wdq);
            }
            for (xv, pv) in x.iter_mut().zip(&ws.proj[..s_len * dm]) {
                *xv += *pv;
            }
            for s in 0..s_len {
                kernels::rms_norm(
                    &x[s * dm..(s + 1) * dm],
                    &lp.ln2.data,
                    &mut ws.xn[s * dm..(s + 1) * dm],
                );
            }
            {
                let xn = &ws.xn[..s_len * dm];
                let ff = &mut ws.ff[..s_len * d_ff];
                matmul(xn, &lp.w_in, ql.map(|q| &q.w_in), s_len, dm, d_ff, ff, &mut ws.wdq);
            }
            for v in ws.ff[..s_len * d_ff].iter_mut() {
                *v = kernels::gelu(*v);
            }
            {
                let ff = &ws.ff[..s_len * d_ff];
                let proj = &mut ws.proj[..s_len * dm];
                matmul(ff, &lp.w_out, ql.map(|q| &q.w_out), s_len, d_ff, dm, proj, &mut ws.wdq);
            }
            for (xv, pv) in x.iter_mut().zip(&ws.proj[..s_len * dm]) {
                *xv += *pv;
            }
        }
    }

    /// Final-norm + vocab projection for a set of (residual offset in
    /// `xs`, output offset in `logits`) jobs, parallelized across jobs —
    /// or across vocab tiles when only one row needs logits.
    fn logits_stage(&self, xs: &[f32], jobs: &[(usize, usize)], logits: &mut [f32]) {
        let dm = self.cfg.d_model;
        let vocab = self.cfg.vocab;
        let embed = &self.params.embed.data;
        // Int8 tier: the vocab projection reads the row-quantized embedding
        // (4x fewer bytes over the largest matrix the decode step touches);
        // the f32 embedding above still serves exact token lookups.
        let qembed = self.quant_params().map(|q| &q.embed);
        let ln_f = &self.params.ln_f.data;
        if jobs.len() == 1 && self.threads > 1 && vocab >= VOCAB_PAR_MIN {
            let (xoff, ooff) = jobs[0];
            let mut ws = self.lease_ws();
            ws.ensure(1, dm, 1, false);
            kernels::rms_norm(&xs[xoff..xoff + dm], ln_f, &mut ws.xn[..dm]);
            let xn = &ws.xn[..dm];
            let out = &mut logits[ooff..ooff + vocab];
            let tile = vocab.div_ceil(self.threads);
            let l_raw = RawSlice::new(out);
            kernels::par_for(vocab.div_ceil(tile), self.threads, |c| {
                let t0 = c * tile;
                let t1 = (t0 + tile).min(vocab);
                // SAFETY: vocab tiles are disjoint.
                let tile_out = unsafe { l_raw.range_mut(t0, t1 - t0) };
                match qembed {
                    Some(q) => kernels::logits_tile_i8(xn, q, t0, t1, tile_out),
                    None => kernels::logits_tile(xn, embed, t0, t1, tile_out),
                }
            });
            self.return_ws(ws);
            return;
        }
        let l_raw = RawSlice::new(logits);
        kernels::par_for(jobs.len(), self.threads, |i| {
            let (xoff, ooff) = jobs[i];
            let mut ws = self.lease_ws();
            ws.ensure(1, dm, 1, false);
            kernels::rms_norm(&xs[xoff..xoff + dm], ln_f, &mut ws.xn[..dm]);
            // SAFETY: each job owns its [vocab] output range.
            let out = unsafe { l_raw.range_mut(ooff, vocab) };
            match qembed {
                Some(q) => kernels::logits_tile_i8(&ws.xn[..dm], q, 0, vocab, out),
                None => kernels::logits_tile(&ws.xn[..dm], embed, 0, vocab, out),
            }
            self.return_ws(ws);
        });
    }

    /// Shared prefill body. `last`: None = logits for all S positions
    /// ([B, S, V]); Some = logits only at `last[b]` per row ([B, V]).
    /// `active`: rows marked false (batch padding) are skipped entirely —
    /// their logits stay 0 and their cache rows stay zeroed.
    /// `seeds`: per-row fetched KV prefixes — positions `0..seeds[b].len`
    /// are installed into the caches instead of computed, and `forward_row`
    /// covers only the suffix (requires `last` mode: cached positions have
    /// no residuals to project logits from).
    fn prefill_impl(
        &self,
        batch: usize,
        tokens: &[i32],
        last: Option<&[usize]>,
        active: Option<&[bool]>,
        seeds: Option<&[SeededPrefix<'_>]>,
    ) -> Result<(Vec<f32>, Tensor, Tensor, usize)> {
        let t_start = Instant::now();
        let seq = *self
            .prefill
            .get(&batch)
            .ok_or_else(|| Error::msg(format!("no prefill artifact for batch {batch}")))?;
        if tokens.len() != batch * seq {
            return Err(Error::msg(format!("tokens len {} != {batch}x{seq}", tokens.len())));
        }
        if let Some(a) = active {
            if a.len() != batch {
                return Err(Error::msg("active mask arity mismatch"));
            }
        }
        if let Some(l) = last {
            if l.len() != batch {
                return Err(Error::msg("last-position arity mismatch"));
            }
            if let Some(&bad) = l.iter().find(|&&p| p >= seq) {
                return Err(Error::msg(format!("last position {bad} outside prefill window {seq}")));
            }
        }
        let cfg = &self.cfg;
        let is_active = |b: usize| match active {
            Some(a) => a[b],
            None => true,
        };
        // Validate the whole [B, S] batch up front: token errors must never
        // leave a partially-written KV cache. Out-of-vocab ids are caller
        // bugs — fail loudly rather than embed a clamped stand-in and
        // generate plausible garbage.
        for b in 0..batch {
            if !is_active(b) {
                continue;
            }
            for s in 0..seq {
                let raw = tokens[b * seq + s];
                if raw < 0 || raw as usize >= cfg.vocab {
                    return Err(Error::msg(format!(
                        "token id {raw} at [{b},{s}] outside vocab {}",
                        cfg.vocab
                    )));
                }
            }
        }
        let seed_len = |b: usize| seeds.map(|s| s[b].len).unwrap_or(0);
        if let Some(s) = seeds {
            if s.len() != batch {
                return Err(Error::msg("seed arity mismatch"));
            }
            let Some(l) = last else {
                return Err(Error::msg("seeded prefill requires last-position mode"));
            };
            for b in 0..batch {
                let sp = &s[b];
                if sp.len == 0 || !is_active(b) {
                    continue;
                }
                if sp.len > l[b] {
                    return Err(Error::msg(format!(
                        "seed covers {} positions but logits are needed at {} — the \
                         last position must be computed, not installed",
                        sp.len, l[b]
                    )));
                }
                let want = cfg.n_layers * sp.len * cfg.d_model;
                if sp.k.len() != want || sp.v.len() != want {
                    return Err(Error::msg(format!(
                        "seed slab for row {b} has {}/{} floats, want {want} per side",
                        sp.k.len(),
                        sp.v.len()
                    )));
                }
            }
        }
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        let mut k_cache = Tensor::zeros(vec![cfg.n_layers, batch, cfg.max_seq, h, hd]);
        // A second zeros, not `k_cache.clone()` — cloning a zero tensor
        // memcpys megabytes for nothing.
        let mut v_cache = Tensor::zeros(vec![cfg.n_layers, batch, cfg.max_seq, h, hd]);
        let per_row = if last.is_some() { cfg.vocab } else { seq * cfg.vocab };
        let mut logits = vec![0.0f32; batch * per_row];
        let n_active = (0..batch).filter(|&b| is_active(b)).count();
        let mut xs = self.lease_buf(batch * seq * dm);

        {
            let k_raw = RawSlice::new(&mut k_cache.data);
            let v_raw = RawSlice::new(&mut v_cache.data);
            let xs_raw = RawSlice::new(&mut xs);
            let embed = &self.params.embed.data;
            kernels::par_for(batch, self.threads.min(n_active.max(1)), |b| {
                if !is_active(b) {
                    return;
                }
                let mut ws = self.lease_ws();
                // Cached prefix first: fetched K/V rows land in the cache
                // slabs by memcpy, then forward_row covers only the suffix
                // — same s0/s_len contract decode already exercises.
                let sl = seed_len(b);
                if sl > 0 {
                    let sp = &seeds.unwrap()[b];
                    kernels::install_kv(sp.k, &k_raw, cfg.n_layers, batch, b, cfg.max_seq, dm, sl);
                    kernels::install_kv(sp.v, &v_raw, cfg.n_layers, batch, b, cfg.max_seq, dm, sl);
                }
                let s_len = seq - sl;
                // SAFETY: per-row residual regions are disjoint.
                let x = unsafe { xs_raw.range_mut(b * seq * dm, s_len * dm) };
                for s in 0..s_len {
                    let tok = tokens[b * seq + sl + s] as usize;
                    x[s * dm..(s + 1) * dm].copy_from_slice(&embed[tok * dm..(tok + 1) * dm]);
                }
                self.forward_row(batch, b, sl, s_len, x, &k_raw, &v_raw, None, &mut ws);
                self.return_ws(ws);
            });
        }

        let jobs: Vec<(usize, usize)> = match last {
            // Row b's residual for absolute position p lives at suffix
            // offset p - seed_len(b) of its region in `xs`.
            Some(l) => (0..batch)
                .filter(|&b| is_active(b))
                .map(|b| ((b * seq + (l[b] - seed_len(b))) * dm, b * cfg.vocab))
                .collect(),
            None => (0..batch)
                .filter(|&b| is_active(b))
                .flat_map(|b| (0..seq).map(move |s| (b, s)))
                .map(|(b, s)| ((b * seq + s) * dm, (b * seq + s) * cfg.vocab))
                .collect(),
        };
        self.logits_stage(&xs, &jobs, &mut logits);
        self.return_buf(xs);
        self.bump_quant_counters(n_active as u64, jobs.len() as u64);

        let seeded_tokens: usize = (0..batch).filter(|&b| is_active(b)).map(seed_len).sum();
        let seeded_rows = (0..batch).filter(|&b| is_active(b) && seed_len(b) > 0).count();
        self.counters.prefill_calls.fetch_add(1, Ordering::Relaxed);
        // `prefill_tokens` counts *computed* positions: seeded rows cost
        // only their suffix; the installed prefix is tracked separately.
        self.counters
            .prefill_tokens
            .fetch_add((n_active * seq - seeded_tokens) as u64, Ordering::Relaxed);
        if seeded_rows > 0 {
            self.counters.seeded_prefill_rows.fetch_add(seeded_rows as u64, Ordering::Relaxed);
            self.counters.seeded_prefill_tokens.fetch_add(seeded_tokens as u64, Ordering::Relaxed);
        }
        self.counters
            .prefill_us
            .fetch_add(t_start.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok((logits, k_cache, v_cache, seq))
    }

    /// Run prefill over `tokens` (row-major [B, S], pre-padded to the
    /// artifact's S; entries are token ids < vocab), producing logits for
    /// every position.
    pub fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<PrefillOut> {
        let (logits, k, v, seq) = self.prefill_impl(batch, tokens, None, None, None)?;
        Ok(PrefillOut { logits, batch, seq, vocab: self.cfg.vocab, k, v })
    }

    /// Prefill computing logits only at `last[b]` per row (the position
    /// `generate` actually consumes) — skips `(S-1) * V` vocab dots per
    /// row versus [`TinyLmRuntime::prefill`]. `active` marks padded batch
    /// rows to skip outright (None = all rows live).
    pub fn prefill_last(
        &self,
        batch: usize,
        tokens: &[i32],
        last: &[usize],
        active: Option<&[bool]>,
    ) -> Result<PrefillLastOut> {
        let (logits, k, v, _seq) = self.prefill_impl(batch, tokens, Some(last), active, None)?;
        Ok(PrefillLastOut { logits, batch, vocab: self.cfg.vocab, k, v })
    }

    /// [`TinyLmRuntime::prefill_last`] seeded from externally fetched KV
    /// (the distributed pool's real-path entry): rows with
    /// `seeds[b].len > 0` get positions `0..len` installed by memcpy and
    /// pay `forward_row` compute only for the suffix `len..S`. The seed
    /// slabs come from a bit-exact earlier prefill of the same token prefix
    /// at the same absolute positions, so logits and both caches are
    /// bit-identical to a cold full prefill (runtime_e2e proptest).
    pub fn prefill_last_seeded(
        &self,
        batch: usize,
        tokens: &[i32],
        last: &[usize],
        active: Option<&[bool]>,
        seeds: &[SeededPrefix<'_>],
    ) -> Result<PrefillLastOut> {
        let (logits, k, v, _seq) =
            self.prefill_impl(batch, tokens, Some(last), active, Some(seeds))?;
        Ok(PrefillLastOut { logits, batch, vocab: self.cfg.vocab, k, v })
    }

    /// One decode step: `token[b]` written at `pos[b]`, attending to
    /// positions <= pos. KV buffers are consumed by value and handed back
    /// in the output — the per-token hot path never copies the cache.
    pub fn decode(
        &self,
        batch: usize,
        token: &[i32],
        pos: &[i32],
        k: DeviceTensor,
        v: DeviceTensor,
    ) -> Result<DecodeOut> {
        self.decode_active(batch, token, pos, k, v, None)
    }

    /// [`TinyLmRuntime::decode`] with an activity mask: rows marked false
    /// (batch padding) are skipped — logits stay 0, cache rows untouched.
    pub fn decode_active(
        &self,
        batch: usize,
        token: &[i32],
        pos: &[i32],
        k: DeviceTensor,
        v: DeviceTensor,
        active: Option<&[bool]>,
    ) -> Result<DecodeOut> {
        let t_start = Instant::now();
        if !self.decode.contains(&batch) {
            return Err(Error::msg(format!("no decode artifact for batch {batch}")));
        }
        if token.len() != batch || pos.len() != batch {
            return Err(Error::msg("decode arg arity mismatch"));
        }
        if let Some(a) = active {
            if a.len() != batch {
                return Err(Error::msg("active mask arity mismatch"));
            }
        }
        let cfg = &self.cfg;
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        if k.dims != [cfg.n_layers, batch, cfg.max_seq, h, hd] {
            return Err(Error::msg(format!("k cache dims {:?} unexpected", k.dims)));
        }
        if v.dims != k.dims {
            return Err(Error::msg(format!("v cache dims {:?} != k dims {:?}", v.dims, k.dims)));
        }
        let is_active = |b: usize| match active {
            Some(a) => a[b],
            None => true,
        };
        // Validate every active row before touching any cache slab.
        for b in 0..batch {
            if !is_active(b) {
                continue;
            }
            if pos[b] < 0 || pos[b] as usize >= cfg.max_seq {
                return Err(Error::msg(format!("decode position {} beyond cache", pos[b])));
            }
            if token[b] < 0 || token[b] as usize >= cfg.vocab {
                return Err(Error::msg(format!(
                    "decode token id {} outside vocab {}",
                    token[b], cfg.vocab
                )));
            }
        }
        let mut k_cache = k;
        let mut v_cache = v;
        let mut logits = vec![0.0f32; batch * cfg.vocab];
        let n_active = (0..batch).filter(|&b| is_active(b)).count();
        let mut xs = self.lease_buf(batch * dm);

        {
            let k_raw = RawSlice::new(&mut k_cache.data);
            let v_raw = RawSlice::new(&mut v_cache.data);
            let xs_raw = RawSlice::new(&mut xs);
            let embed = &self.params.embed.data;
            kernels::par_for(batch, self.threads.min(n_active.max(1)), |b| {
                if !is_active(b) {
                    return;
                }
                let mut ws = self.lease_ws();
                let tok = token[b] as usize;
                // SAFETY: per-row residual regions are disjoint.
                let x = unsafe { xs_raw.range_mut(b * dm, dm) };
                x.copy_from_slice(&embed[tok * dm..(tok + 1) * dm]);
                self.forward_row(batch, b, pos[b] as usize, 1, x, &k_raw, &v_raw, None, &mut ws);
                self.return_ws(ws);
            });
        }

        let jobs: Vec<(usize, usize)> = (0..batch)
            .filter(|&b| is_active(b))
            .map(|b| (b * dm, b * cfg.vocab))
            .collect();
        self.logits_stage(&xs, &jobs, &mut logits);
        self.return_buf(xs);
        self.bump_quant_counters(n_active as u64, jobs.len() as u64);

        self.counters.decode_calls.fetch_add(1, Ordering::Relaxed);
        self.counters.decode_tokens.fetch_add(n_active as u64, Ordering::Relaxed);
        self.counters
            .decode_us
            .fetch_add(t_start.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(DecodeOut { logits, vocab: cfg.vocab, k: k_cache, v: v_cache })
    }

    /// One iteration of an event-driven scheduler: a heterogeneous set of
    /// [`RowChunk`]s — some rows advancing a chunked prefill, some taking a
    /// single decode step — computed in one parallel sweep over a shared
    /// persistent cache pair. This is the continuous-batching entry point:
    /// unlike [`TinyLmRuntime::prefill`], the caches are caller-owned and
    /// span the scheduler's whole slot array, rows join/leave between
    /// iterations, and only the positions named by the chunks are touched.
    ///
    /// Exactness: each chunk runs the same [`TinyLmRuntime::forward_row`]
    /// body prefill and decode use, and every K/V entry is a deterministic
    /// function of the tokens at positions `<=` its own — so any chunking
    /// of a prompt (including resuming after preemption) is bit-identical
    /// to the one-shot prefill, and rows never observe each other.
    ///
    /// Requires the decode artifact for `batch` (iteration steps ride the
    /// persistent decode-shaped caches, `[L, batch, max_seq, H, Dh]`).
    /// Rows may appear at most once per call; a chunk's `seed` installs a
    /// fetched KV prefix and requires `s0 == seed.len`.
    pub fn prefill_chunk(
        &self,
        batch: usize,
        chunks: &[RowChunk<'_>],
        k: DeviceTensor,
        v: DeviceTensor,
    ) -> Result<ChunkOut> {
        let t_start = Instant::now();
        if !self.decode.contains(&batch) {
            return Err(Error::msg(format!("no decode artifact for batch {batch}")));
        }
        if chunks.is_empty() {
            return Err(Error::msg("prefill_chunk called with no chunks"));
        }
        let cfg = &self.cfg;
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        if k.dims != [cfg.n_layers, batch, cfg.max_seq, h, hd] {
            return Err(Error::msg(format!("k cache dims {:?} unexpected", k.dims)));
        }
        if v.dims != k.dims {
            return Err(Error::msg(format!("v cache dims {:?} != k dims {:?}", v.dims, k.dims)));
        }
        // Validate every chunk before touching any cache slab: a token
        // error must never leave a partially-written row.
        let mut seen = vec![false; batch];
        for c in chunks {
            if c.row >= batch {
                return Err(Error::msg(format!("chunk row {} outside batch {batch}", c.row)));
            }
            if seen[c.row] {
                return Err(Error::msg(format!("row {} appears in two chunks", c.row)));
            }
            seen[c.row] = true;
            if c.tokens.is_empty() {
                return Err(Error::msg(format!("empty chunk for row {}", c.row)));
            }
            if c.s0 + c.tokens.len() > cfg.max_seq {
                return Err(Error::msg(format!(
                    "chunk [{}..{}) of row {} beyond cache {}",
                    c.s0,
                    c.s0 + c.tokens.len(),
                    c.row,
                    cfg.max_seq
                )));
            }
            if let Some(&bad) = c.tokens.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab) {
                return Err(Error::msg(format!(
                    "token id {bad} in row {} chunk outside vocab {}",
                    c.row, cfg.vocab
                )));
            }
            if let Some(sp) = &c.seed {
                if sp.len > 0 {
                    if c.s0 != sp.len {
                        return Err(Error::msg(format!(
                            "seed covers {} positions but chunk starts at {} — a seeded \
                             chunk must resume exactly where the installed prefix ends",
                            sp.len, c.s0
                        )));
                    }
                    let want = cfg.n_layers * sp.len * dm;
                    if sp.k.len() != want || sp.v.len() != want {
                        return Err(Error::msg(format!(
                            "seed slab for row {} has {}/{} floats, want {want} per side",
                            c.row,
                            sp.k.len(),
                            sp.v.len()
                        )));
                    }
                }
            }
            if let Some(qs) = &c.qseed {
                if qs.len > 0 {
                    if c.seed.map(|s| s.len > 0).unwrap_or(false) {
                        return Err(Error::msg(format!(
                            "row {} carries both an f32 and an int8 seed",
                            c.row
                        )));
                    }
                    if c.s0 != qs.len {
                        return Err(Error::msg(format!(
                            "int8 seed covers {} positions but chunk starts at {} — a \
                             seeded chunk must resume exactly where the prefix ends",
                            qs.len, c.s0
                        )));
                    }
                    let want = cfg.n_layers * qs.len * dm;
                    let rows = cfg.n_layers * qs.len;
                    if qs.k.len() != want || qs.v.len() != want {
                        return Err(Error::msg(format!(
                            "int8 seed slab for row {} has {}/{} bytes, want {want} per side",
                            c.row,
                            qs.k.len(),
                            qs.v.len()
                        )));
                    }
                    if qs.k_scales.len() != rows || qs.v_scales.len() != rows {
                        return Err(Error::msg(format!(
                            "int8 seed scales for row {} have {}/{} entries, want {rows}",
                            c.row,
                            qs.k_scales.len(),
                            qs.v_scales.len()
                        )));
                    }
                }
            }
        }
        let mut k_cache = k;
        let mut v_cache = v;
        let mut logits = vec![0.0f32; batch * cfg.vocab];
        // Prefix-sum residual offsets: chunk i owns xs[offs[i] .. offs[i] +
        // len_i*dm].
        let mut offs = Vec::with_capacity(chunks.len());
        let mut total = 0usize;
        for c in chunks {
            offs.push(total * dm);
            total += c.tokens.len();
        }
        let mut xs = self.lease_buf(total * dm);

        self.chunk_forward(batch, chunks, &offs, &mut xs, &mut k_cache.data, &mut v_cache.data);

        // Logits only where the scheduler samples: each emitting chunk's
        // last position, written to its row's [V] slot.
        let jobs: Vec<(usize, usize)> = chunks
            .iter()
            .zip(&offs)
            .filter(|(c, _)| c.emit_logits)
            .map(|(c, &off)| (off + (c.tokens.len() - 1) * dm, c.row * cfg.vocab))
            .collect();
        self.logits_stage(&xs, &jobs, &mut logits);
        self.return_buf(xs);
        self.bump_quant_counters(chunks.len() as u64, jobs.len() as u64);

        // Telemetry: attribute decode chunks and prefill chunks to their
        // own counter families so tok/s and hit-rate math stay meaningful
        // under interleaving.
        let dec_toks: u64 = chunks.iter().filter(|c| c.decode).map(|c| c.tokens.len() as u64).sum();
        let pre_toks: u64 = chunks.iter().filter(|c| !c.decode).map(|c| c.tokens.len() as u64).sum();
        let seeded = |c: &RowChunk<'_>| {
            c.seed.map(|s| s.len).unwrap_or(0) + c.qseed.map(|s| s.len).unwrap_or(0)
        };
        let seeded_rows = chunks.iter().filter(|c| seeded(c) > 0).count() as u64;
        let seeded_toks: u64 = chunks.iter().map(|c| seeded(c) as u64).sum();
        let elapsed = t_start.elapsed().as_micros() as u64;
        if pre_toks > 0 {
            self.counters.prefill_calls.fetch_add(1, Ordering::Relaxed);
            self.counters.prefill_tokens.fetch_add(pre_toks, Ordering::Relaxed);
            // Mixed iterations bill wall time to prefill (it dominates).
            self.counters.prefill_us.fetch_add(elapsed, Ordering::Relaxed);
        }
        if dec_toks > 0 {
            self.counters.decode_calls.fetch_add(1, Ordering::Relaxed);
            self.counters.decode_tokens.fetch_add(dec_toks, Ordering::Relaxed);
            if pre_toks == 0 {
                self.counters.decode_us.fetch_add(elapsed, Ordering::Relaxed);
            }
        }
        if seeded_rows > 0 {
            self.counters.seeded_prefill_rows.fetch_add(seeded_rows, Ordering::Relaxed);
            self.counters.seeded_prefill_tokens.fetch_add(seeded_toks, Ordering::Relaxed);
        }
        Ok(ChunkOut { logits, vocab: cfg.vocab, k: k_cache, v: v_cache })
    }

    /// Compute stage of [`TinyLmRuntime::prefill_chunk`]: install seeds,
    /// embed, and forward every chunk in parallel. Split out from the
    /// validation/allocation prologue so the per-iteration loop stays
    /// allocation-free.
    // lint:hot_path
    fn chunk_forward(
        &self,
        batch: usize,
        chunks: &[RowChunk<'_>],
        offs: &[usize],
        xs: &mut [f32],
        k_cache: &mut [f32],
        v_cache: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let dm = cfg.d_model;
        let k_raw = RawSlice::new(k_cache);
        let v_raw = RawSlice::new(v_cache);
        let xs_raw = RawSlice::new(xs);
        let embed = &self.params.embed.data;
        kernels::par_for(chunks.len(), self.threads.min(chunks.len()), |i| {
            let c = &chunks[i];
            let mut ws = self.lease_ws();
            if let Some(sp) = c.seed {
                if sp.len > 0 {
                    // Fetched prefix first, by memcpy — same s0/s_len
                    // resume contract the seeded prefill path exercises.
                    kernels::install_kv(
                        sp.k, &k_raw, cfg.n_layers, batch, c.row, cfg.max_seq, dm, sp.len,
                    );
                    kernels::install_kv(
                        sp.v, &v_raw, cfg.n_layers, batch, c.row, cfg.max_seq, dm, sp.len,
                    );
                }
            }
            let qseed = c.qseed.filter(|qs| qs.len > 0);
            if let Some(qs) = qseed {
                // Int8 prefix: the suffix below attends directly over the
                // i8 slabs; the dequantized expansion still lands in the
                // f32 cache because later decode steps attend over the
                // whole row with the f32 kernel. Same bits either way.
                kernels::install_kv_i8(
                    qs.k, qs.k_scales, &k_raw, cfg.n_layers, batch, c.row, cfg.max_seq, dm, qs.len,
                );
                kernels::install_kv_i8(
                    qs.v, qs.v_scales, &v_raw, cfg.n_layers, batch, c.row, cfg.max_seq, dm, qs.len,
                );
            }
            let s_len = c.tokens.len();
            // SAFETY: per-chunk residual regions are disjoint (prefix-sum
            // offsets), and each row appears in at most one chunk.
            let x = unsafe { xs_raw.range_mut(offs[i], s_len * dm) };
            for (s, &t) in c.tokens.iter().enumerate() {
                let tok = t as usize;
                x[s * dm..(s + 1) * dm].copy_from_slice(&embed[tok * dm..(tok + 1) * dm]);
            }
            self.forward_row(batch, c.row, c.s0, s_len, x, &k_raw, &v_raw, qseed, &mut ws);
            self.return_ws(ws);
        });
    }

    /// Greedy-generate `steps` tokens for a batch of prompts (lengths may
    /// differ; prompts are padded to the prefill S). Returns per-row
    /// generated token ids. The workhorse of `RealEngine` / serve_e2e.
    pub fn generate(&self, prompts: &[Vec<u32>], steps: usize) -> Result<Vec<Vec<u32>>> {
        self.generate_masked(prompts, steps, None)
    }

    /// [`TinyLmRuntime::generate`] with an activity mask: rows marked
    /// false (the engine's batch padding) are skipped at every step and
    /// yield all-zero token rows.
    pub fn generate_masked(
        &self,
        prompts: &[Vec<u32>],
        steps: usize,
        active: Option<&[bool]>,
    ) -> Result<Vec<Vec<u32>>> {
        Ok(self.generate_seeded(prompts, steps, active, None)?.0)
    }

    /// [`TinyLmRuntime::generate_masked`] with optional per-row KV seeds
    /// (see [`TinyLmRuntime::prefill_last_seeded`]), returning the final
    /// K/V caches alongside the tokens so the caller can extract the
    /// prompt-prefix blocks for pool write-back — decode writes only at
    /// positions `>= prompt_len`, so the prompt rows are exactly the
    /// prefill's bits.
    pub fn generate_seeded(
        &self,
        prompts: &[Vec<u32>],
        steps: usize,
        active: Option<&[bool]>,
        seeds: Option<&[SeededPrefix<'_>]>,
    ) -> Result<(Vec<Vec<u32>>, DeviceTensor, DeviceTensor)> {
        let batch = prompts.len();
        let seq = *self
            .prefill
            .get(&batch)
            .ok_or_else(|| Error::msg(format!("no prefill artifact for batch {batch}")))?;
        let max_new = self.cfg.max_seq - seq;
        if steps > max_new {
            return Err(Error::msg(format!("steps {steps} exceeds cache headroom {max_new}")));
        }
        let mut tokens = vec![0i32; batch * seq];
        for (b, p) in prompts.iter().enumerate() {
            if p.len() > seq {
                return Err(Error::msg(format!("prompt {b} longer than prefill window {seq}")));
            }
            for (s, &t) in p.iter().enumerate() {
                tokens[b * seq + s] = t as i32;
            }
        }
        let last: Vec<usize> = prompts.iter().map(|p| p.len().saturating_sub(1)).collect();
        let pre = match seeds {
            Some(s) => self.prefill_last_seeded(batch, &tokens, &last, active, s)?,
            None => self.prefill_last(batch, &tokens, &last, active)?,
        };
        let mut cur: Vec<i32> = (0..batch).map(|b| pre.argmax_of(b) as i32).collect();
        let mut k = pre.k;
        let mut v = pre.v;
        let mut out: Vec<Vec<u32>> = cur.iter().map(|&t| vec![t as u32]).collect();
        // Decode continues each row at its true length.
        let mut pos: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        for _ in 1..steps {
            let d = self.decode_active(batch, &cur, &pos, k, v, active)?;
            for b in 0..batch {
                cur[b] = d.argmax_of(b) as i32;
                out[b].push(cur[b] as u32);
                pos[b] += 1;
            }
            k = d.k;
            v = d.v;
        }
        Ok((out, k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny in-memory runtime (2 layers, vocab 16) for interpreter checks —
    /// no artifacts needed. Pinned to the f32 contract tier so a stray
    /// `AIBRIX_RT_PRECISION` in the environment cannot flip the bit-exact
    /// tests onto the quant path.
    fn toy_runtime() -> TinyLmRuntime {
        let mut rt = TinyLmRuntime::synthetic(&SyntheticSpec::tiny());
        rt.set_precision(Precision::F32);
        rt
    }

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        let rt = toy_runtime();
        let prompts = vec![vec![1u32, 2, 3]];
        let a = rt.generate(&prompts, 4).unwrap();
        let b = rt.generate(&prompts, 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 4);
        assert!(a[0].iter().all(|&t| t < 16));
    }

    #[test]
    fn batch_rows_independent() {
        let rt = toy_runtime();
        let solo = rt.generate(&[vec![5u32, 6, 7]].to_vec(), 3).unwrap();
        let batch = rt.generate(&vec![vec![5u32, 6, 7], vec![9u32, 1]], 3).unwrap();
        assert_eq!(batch[0], solo[0]);
    }

    #[test]
    fn decode_matches_re_prefill() {
        // The KV-cache decode path must chain bit-exactly into prefill: the
        // second generated token equals a fresh prefill of prompt+token1.
        let rt = toy_runtime();
        let prompt = vec![3u32, 8, 2];
        let gen = rt.generate(&[prompt.clone()].to_vec(), 3).unwrap();
        let mut longer = prompt.clone();
        longer.push(gen[0][0]);
        let gen2 = rt.generate(&[longer].to_vec(), 2).unwrap();
        assert_eq!(gen2[0][0], gen[0][1]);
    }

    #[test]
    fn prefill_last_matches_full_prefill() {
        // The positions-mask path must be a pure subset of the full one:
        // identical bits at the selected positions, identical caches.
        let rt = toy_runtime();
        let tokens: Vec<i32> = vec![3, 8, 2, 1, 0, 0, 0, 0, 9, 4, 4, 7, 1, 0, 0, 0];
        let full = rt.prefill(2, &tokens).unwrap();
        let last = [3usize, 5];
        let fast = rt.prefill_last(2, &tokens, &last, None).unwrap();
        for b in 0..2 {
            assert!(
                fast.logits_of(b)
                    .iter()
                    .zip(full.logits_at(b, last[b]))
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "row {b} logits diverge"
            );
        }
        assert!(fast.k.data.iter().zip(&full.k.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(fast.v.data.iter().zip(&full.v.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn masked_rows_do_not_disturb_active_rows() {
        // A padded (inactive) neighbor row must leave the active row's
        // output exactly as a solo run, and produce all-zero tokens itself.
        let rt = toy_runtime();
        let solo = rt.generate(&[vec![5u32, 6, 7]].to_vec(), 3).unwrap();
        let masked = rt
            .generate_masked(&[vec![5u32, 6, 7], vec![0u32]].to_vec(), 3, Some(&[true, false]))
            .unwrap();
        assert_eq!(masked[0], solo[0]);
        assert!(masked[1].iter().all(|&t| t == 0));
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let spec = SyntheticSpec::tiny();
        let mut rt1 = TinyLmRuntime::synthetic(&spec);
        rt1.set_threads(1);
        let mut rt4 = TinyLmRuntime::synthetic(&spec);
        rt4.set_threads(4);
        let tokens: Vec<i32> = vec![3, 8, 2, 1, 5, 11, 0, 2, 9, 4, 4, 7, 1, 15, 2, 6];
        let a = rt1.prefill(2, &tokens).unwrap();
        let b = rt4.prefill(2, &tokens).unwrap();
        assert!(a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.k.data.iter().zip(&b.k.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        let g1 = rt1.generate(&[vec![1u32, 2, 3], vec![9, 8]].to_vec(), 4).unwrap();
        let g4 = rt4.generate(&[vec![1u32, 2, 3], vec![9, 8]].to_vec(), 4).unwrap();
        assert_eq!(g1, g4);
    }

    #[test]
    fn vocab_tile_parallel_matches_serial() {
        // A single-row logits job with vocab >= VOCAB_PAR_MIN takes the
        // vocab-tile-parallel path; it must match the serial bits exactly.
        let spec = SyntheticSpec {
            cfg: ModelCfg {
                vocab: VOCAB_PAR_MIN,
                d_model: 8,
                n_layers: 1,
                n_heads: 2,
                head_dim: 4,
                max_seq: 8,
                page_size: 4,
            },
            d_ff: 16,
            prefill: vec![(1, 4)],
            decode: vec![1],
            seed: 3,
        };
        let mut rt1 = TinyLmRuntime::synthetic(&spec);
        rt1.set_threads(1);
        let mut rt4 = TinyLmRuntime::synthetic(&spec);
        rt4.set_threads(4);
        let tokens = [5i32, 900, 17, 1023];
        let a = rt1.prefill_last(1, &tokens, &[3], None).unwrap();
        let b = rt4.prefill_last(1, &tokens, &[3], None).unwrap();
        assert!(a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits()));
        // And the decode-side single-row logits path.
        let da = rt1.decode(1, &[7], &[4], a.k, a.v).unwrap();
        let db = rt4.decode(1, &[7], &[4], b.k, b.v).unwrap();
        assert!(da.logits.iter().zip(&db.logits).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    /// Slice the `[L, len, Dm]` seed slab for row `b` out of a full cache
    /// tensor (what `kvcache::blocks::assemble_prefix` produces on the
    /// real path).
    fn seed_slab(cache: &Tensor, cfg: &ModelCfg, batch: usize, b: usize, len: usize) -> Vec<f32> {
        let dm = cfg.d_model;
        let mut slab = Vec::with_capacity(cfg.n_layers * len * dm);
        for layer in 0..cfg.n_layers {
            let base = (layer * batch + b) * cfg.max_seq * dm;
            slab.extend_from_slice(&cache.data[base..base + len * dm]);
        }
        slab
    }

    #[test]
    fn seeded_prefill_matches_cold_prefill() {
        // Install the first 4 positions from an earlier prefill's caches;
        // logits and both caches must be bit-identical to the cold run.
        let rt = toy_runtime();
        let tokens: Vec<i32> = vec![3, 8, 2, 1, 7, 5, 0, 9, 9, 4, 4, 7, 1, 2, 6, 0];
        let last = [7usize, 6];
        let cold = rt.prefill_last(2, &tokens, &last, None).unwrap();
        let full = rt.prefill(2, &tokens).unwrap();
        let (k0, v0) = (seed_slab(&full.k, &rt.cfg, 2, 0, 4), seed_slab(&full.v, &rt.cfg, 2, 0, 4));
        let seeds = [
            SeededPrefix { len: 4, k: &k0, v: &v0 },
            SeededPrefix::default(), // row 1 stays cold
        ];
        let warm = rt.prefill_last_seeded(2, &tokens, &last, None, &seeds).unwrap();
        for b in 0..2 {
            assert!(
                warm.logits_of(b)
                    .iter()
                    .zip(cold.logits_of(b))
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "row {b} seeded logits diverge"
            );
        }
        assert!(warm.k.data.iter().zip(&cold.k.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(warm.v.data.iter().zip(&cold.v.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn seeded_generate_matches_cold_generate() {
        let rt = toy_runtime();
        let prompts = vec![vec![5u32, 6, 7, 1, 2, 3]];
        let (cold, k, v) = rt.generate_seeded(&prompts, 3, None, None).unwrap();
        let (ks, vs) =
            (seed_slab(&k, &rt.cfg, 1, 0, 4), seed_slab(&v, &rt.cfg, 1, 0, 4));
        let seeds = [SeededPrefix { len: 4, k: &ks, v: &vs }];
        let (warm, _, _) = rt.generate_seeded(&prompts, 3, None, Some(&seeds)).unwrap();
        assert_eq!(warm, cold, "seeded decode chain must reproduce the cold tokens");
        let s = rt.stats();
        assert_eq!(s.seeded_prefill_rows, 1);
        assert_eq!(s.seeded_prefill_tokens, 4);
    }

    #[test]
    fn seeded_prefill_error_paths() {
        let rt = toy_runtime();
        let tokens = vec![1i32; 8];
        let slab = vec![0.0f32; rt.cfg.n_layers * 4 * rt.cfg.d_model];
        // Seed reaching the last position: nothing left to compute there.
        let seeds = [SeededPrefix { len: 4, k: &slab, v: &slab }];
        assert!(
            rt.prefill_last_seeded(1, &tokens, &[3], None, &seeds).is_err(),
            "seed must stay below the last position"
        );
        // Wrong slab size.
        let short = vec![0.0f32; 3];
        let bad = [SeededPrefix { len: 4, k: &short, v: &short }];
        assert!(rt.prefill_last_seeded(1, &tokens, &[7], None, &bad).is_err());
        // Arity mismatch.
        assert!(rt
            .prefill_last_seeded(1, &tokens, &[7], None, &[])
            .is_err());
    }

    /// Fresh decode-shaped cache pair for chunked-iteration tests.
    fn sched_caches(rt: &TinyLmRuntime, batch: usize) -> (Tensor, Tensor) {
        let c = &rt.cfg;
        let dims = vec![c.n_layers, batch, c.max_seq, c.n_heads, c.head_dim];
        (Tensor::zeros(dims.clone()), Tensor::zeros(dims))
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        // Any split of a prompt into chunks must reproduce the one-shot
        // prefill bit for bit: logits at the last position AND every
        // computed cache entry.
        let rt = toy_runtime();
        let prompt = [3i32, 8, 2, 1, 7, 5, 9];
        let mut padded = prompt.to_vec();
        padded.resize(8, 0);
        let one_shot = rt.prefill_last(1, &padded, &[6], None).unwrap();
        for split in [1usize, 3, 6] {
            let (k, v) = sched_caches(&rt, 1);
            let first = [RowChunk {
                row: 0,
                s0: 0,
                tokens: &prompt[..split],
                seed: None,
                qseed: None,
                emit_logits: false,
                decode: false,
            }];
            let mid = rt.prefill_chunk(1, &first, k, v).unwrap();
            let second = [RowChunk {
                row: 0,
                s0: split,
                tokens: &prompt[split..],
                seed: None,
                qseed: None,
                emit_logits: true,
                decode: false,
            }];
            let out = rt.prefill_chunk(1, &second, mid.k, mid.v).unwrap();
            assert!(
                out.logits_of(0)
                    .iter()
                    .zip(one_shot.logits_of(0))
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "split {split}: chunked logits diverge from one-shot"
            );
            // Cache prefix (the one-shot run also computed padding
            // positions past the prompt; compare only what both wrote).
            let dm = rt.cfg.d_model;
            for layer in 0..rt.cfg.n_layers {
                let base = layer * rt.cfg.max_seq * dm;
                let n = prompt.len() * dm;
                assert!(
                    out.k.data[base..base + n]
                        .iter()
                        .zip(&one_shot.k.data[base..base + n])
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "split {split}: layer {layer} K cache diverges"
                );
                assert!(
                    out.v.data[base..base + n]
                        .iter()
                        .zip(&one_shot.v.data[base..base + n])
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "split {split}: layer {layer} V cache diverges"
                );
            }
        }
    }

    #[test]
    fn chunk_decode_chain_matches_generate() {
        // Chunked prefill followed by single-token decode chunks must
        // reproduce the lockstep generate() tokens exactly.
        let rt = toy_runtime();
        let prompt = vec![5u32, 6, 7, 1, 2];
        let reference = rt.generate(&[prompt.clone()].to_vec(), 3).unwrap();
        let toks: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        let (k, v) = sched_caches(&rt, 1);
        let c1 = [RowChunk {
            row: 0,
            s0: 0,
            tokens: &toks[..2],
            seed: None,
            qseed: None,
            emit_logits: false,
            decode: false,
        }];
        let o1 = rt.prefill_chunk(1, &c1, k, v).unwrap();
        let c2 = [RowChunk {
            row: 0,
            s0: 2,
            tokens: &toks[2..],
            seed: None,
            qseed: None,
            emit_logits: true,
            decode: false,
        }];
        let o2 = rt.prefill_chunk(1, &c2, o1.k, o1.v).unwrap();
        let mut got = vec![o2.argmax_of(0)];
        let (mut k, mut v) = (o2.k, o2.v);
        for step in 0..2usize {
            let cur = [got[got.len() - 1] as i32];
            let c = [RowChunk {
                row: 0,
                s0: prompt.len() + step,
                tokens: &cur,
                seed: None,
                qseed: None,
                emit_logits: true,
                decode: true,
            }];
            let o = rt.prefill_chunk(1, &c, k, v).unwrap();
            got.push(o.argmax_of(0));
            k = o.k;
            v = o.v;
        }
        assert_eq!(got, reference[0], "chunk+decode chain diverges from generate");
        let s = rt.stats();
        assert!(s.decode_tokens >= 2, "decode chunks must bill the decode counters");
    }

    #[test]
    fn mixed_prefill_decode_rows_are_independent() {
        // One iteration mixing a decode row and a prefill row must leave
        // both rows bit-identical to their solo runs — the continuous
        // batching contract.
        let rt = toy_runtime();
        let a = vec![5u32, 6, 7];
        let b = [9i32, 1, 4, 4, 7, 2];
        let solo_a = rt.generate(&[a.clone()].to_vec(), 3).unwrap();
        let mut padded_b = b.to_vec();
        padded_b.resize(8, 0);
        let solo_b = rt.prefill_last(1, &padded_b, &[b.len() - 1], None).unwrap();

        let toks_a: Vec<i32> = a.iter().map(|&t| t as i32).collect();
        let (k, v) = sched_caches(&rt, 2);
        // Iteration 1: row 0 finishes its prompt; row 1 starts a chunk.
        let it1 = [
            RowChunk { row: 0, s0: 0, tokens: &toks_a, seed: None, qseed: None, emit_logits: true, decode: false },
            RowChunk { row: 1, s0: 0, tokens: &b[..3], seed: None, qseed: None, emit_logits: false, decode: false },
        ];
        let o1 = rt.prefill_chunk(2, &it1, k, v).unwrap();
        let g0 = o1.argmax_of(0);
        // Iteration 2: row 0 decodes while row 1 finishes prefilling.
        let cur = [g0 as i32];
        let it2 = [
            RowChunk { row: 0, s0: 3, tokens: &cur, seed: None, qseed: None, emit_logits: true, decode: true },
            RowChunk { row: 1, s0: 3, tokens: &b[3..], seed: None, qseed: None, emit_logits: true, decode: false },
        ];
        let o2 = rt.prefill_chunk(2, &it2, o1.k, o1.v).unwrap();
        assert_eq!(g0, solo_a[0][0]);
        assert_eq!(o2.argmax_of(0), solo_a[0][1], "decode row disturbed by prefill neighbor");
        assert!(
            o2.logits_of(1)
                .iter()
                .zip(solo_b.logits_of(0))
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "prefill row disturbed by decode neighbor"
        );
    }

    #[test]
    fn seeded_chunk_matches_cold_chunk() {
        // Resuming a row from a pool-fetched KV prefix (the preemption /
        // staging path) must be bit-identical to computing it cold.
        let rt = toy_runtime();
        let prompt = [3i32, 8, 2, 1, 7, 5, 9];
        let (k, v) = sched_caches(&rt, 1);
        let cold_chunks = [RowChunk {
            row: 0,
            s0: 0,
            tokens: &prompt,
            seed: None,
            qseed: None,
            emit_logits: true,
            decode: false,
        }];
        let cold = rt.prefill_chunk(1, &cold_chunks, k, v).unwrap();
        let (ks, vs) = (seed_slab(&cold.k, &rt.cfg, 1, 0, 4), seed_slab(&cold.v, &rt.cfg, 1, 0, 4));
        let (k2, v2) = sched_caches(&rt, 1);
        let warm_chunks = [RowChunk {
            row: 0,
            s0: 4,
            tokens: &prompt[4..],
            seed: Some(SeededPrefix { len: 4, k: &ks, v: &vs }),
            qseed: None,
            emit_logits: true,
            decode: false,
        }];
        let warm = rt.prefill_chunk(1, &warm_chunks, k2, v2).unwrap();
        assert!(
            warm.logits_of(0).iter().zip(cold.logits_of(0)).all(|(x, y)| x.to_bits() == y.to_bits()),
            "seeded chunk diverges from cold chunk"
        );
        assert!(warm.k.data.iter().zip(&cold.k.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        let s = rt.stats();
        assert_eq!(s.seeded_prefill_rows, 1);
        assert_eq!(s.seeded_prefill_tokens, 4);
    }

    #[test]
    fn int8_seeded_chunk_matches_dequantized_seed() {
        // The direct-int8 resume path (qseed: attend_one_i8 over the
        // pool's bytes) must be bit-identical to resuming from the
        // dequantized f32 expansion of the same bytes — logits AND every
        // cache entry. This is the contract that lets the real engine
        // attend straight over int8-resident KV while its f32 lockstep
        // twin dequantizes first.
        let rt = toy_runtime();
        let prompt = [3i32, 8, 2, 1, 7, 5, 9];
        let (k, v) = sched_caches(&rt, 1);
        let cold_chunks = [RowChunk {
            row: 0,
            s0: 0,
            tokens: &prompt,
            seed: None,
            qseed: None,
            emit_logits: true,
            decode: false,
        }];
        let cold = rt.prefill_chunk(1, &cold_chunks, k, v).unwrap();
        let len = 4usize;
        let (ks, vs) =
            (seed_slab(&cold.k, &rt.cfg, 1, 0, len), seed_slab(&cold.v, &rt.cfg, 1, 0, len));
        // Quantize the [L, len, Dm] slabs with one scale per (layer, pos)
        // row — the QuantKvBlock orientation — then build both seeds.
        let rows = rt.cfg.n_layers * len;
        let kq = kernels::quantize_rows(&ks, rows, rt.cfg.d_model);
        let vq = kernels::quantize_rows(&vs, rows, rt.cfg.d_model);
        let dq = |q: &kernels::QuantMat| -> Vec<f32> {
            let mut out = vec![0.0f32; q.rows * q.cols];
            for r in 0..q.rows {
                for c in 0..q.cols {
                    out[r * q.cols + c] = f32::from(q.data[r * q.cols + c]) * q.scales[r];
                }
            }
            out
        };
        let (dk, dv) = (dq(&kq), dq(&vq));
        let run = |seed: Option<SeededPrefix<'_>>, qseed: Option<QuantSeededPrefix<'_>>| {
            let (k, v) = sched_caches(&rt, 1);
            let chunks = [RowChunk {
                row: 0,
                s0: len,
                tokens: &prompt[len..],
                seed,
                qseed,
                emit_logits: true,
                decode: false,
            }];
            rt.prefill_chunk(1, &chunks, k, v).unwrap()
        };
        let f32_leg = run(Some(SeededPrefix { len, k: &dk, v: &dv }), None);
        let i8_leg = run(
            None,
            Some(QuantSeededPrefix {
                len,
                k: &kq.data,
                v: &vq.data,
                k_scales: &kq.scales,
                v_scales: &vq.scales,
            }),
        );
        assert!(
            i8_leg
                .logits_of(0)
                .iter()
                .zip(f32_leg.logits_of(0))
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "int8-seeded logits diverge from dequantized-seed logits"
        );
        assert!(i8_leg.k.data.iter().zip(&f32_leg.k.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(i8_leg.v.data.iter().zip(&f32_leg.v.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        // Both legs bill the seeded-prefill telemetry.
        let s = rt.stats();
        assert_eq!(s.seeded_prefill_rows, 2);
        assert_eq!(s.seeded_prefill_tokens, 2 * len as u64);
        // Guard rails: double-seeding and bad scale lengths are errors.
        let both = [RowChunk {
            row: 0,
            s0: len,
            tokens: &prompt[len..],
            seed: Some(SeededPrefix { len, k: &dk, v: &dv }),
            qseed: Some(QuantSeededPrefix {
                len,
                k: &kq.data,
                v: &vq.data,
                k_scales: &kq.scales,
                v_scales: &vq.scales,
            }),
            emit_logits: true,
            decode: false,
        }];
        let (k, v) = sched_caches(&rt, 1);
        assert!(rt.prefill_chunk(1, &both, k, v).is_err(), "both seeds on one row must error");
        let short = [RowChunk {
            row: 0,
            s0: len,
            tokens: &prompt[len..],
            seed: None,
            qseed: Some(QuantSeededPrefix {
                len,
                k: &kq.data,
                v: &vq.data,
                k_scales: &kq.scales[..rows - 1],
                v_scales: &vq.scales,
            }),
            emit_logits: true,
            decode: false,
        }];
        let (k, v) = sched_caches(&rt, 1);
        assert!(rt.prefill_chunk(1, &short, k, v).is_err(), "short scales must error");
    }

    #[test]
    fn chunk_error_paths() {
        let rt = toy_runtime();
        const TOKS: [i32; 2] = [1, 2];
        fn mk(row: usize, s0: usize, seed: Option<SeededPrefix<'_>>) -> RowChunk<'_> {
            RowChunk { row, s0, tokens: &TOKS, seed, qseed: None, emit_logits: true, decode: false }
        }
        let run = |chunks: &[RowChunk<'_>]| {
            let (k, v) = sched_caches(&rt, 2);
            rt.prefill_chunk(2, chunks, k, v)
        };
        // No decode artifact for batch 3.
        let (k3, v3) = sched_caches(&rt, 3);
        assert!(rt.prefill_chunk(3, &[mk(0, 0, None)], k3, v3).is_err());
        // Empty chunk list, row out of range, duplicate row, chunk past
        // the cache end, out-of-vocab token, seed/s0 mismatch.
        assert!(run(&[]).is_err());
        assert!(run(&[mk(2, 0, None)]).is_err());
        assert!(run(&[mk(0, 0, None), mk(0, 2, None)]).is_err());
        assert!(run(&[mk(0, 11, None)]).is_err());
        let bad_tok = [99i32];
        assert!(run(&[RowChunk {
            row: 0,
            s0: 0,
            tokens: &bad_tok,
            seed: None,
            qseed: None,
            emit_logits: true,
            decode: false,
        }])
        .is_err());
        let slab = vec![0.0f32; rt.cfg.n_layers * 4 * rt.cfg.d_model];
        assert!(run(&[mk(0, 2, Some(SeededPrefix { len: 4, k: &slab, v: &slab }))]).is_err());
        // And the happy path still works on the same runtime.
        assert!(run(&[mk(0, 0, None)]).is_ok());
    }

    #[test]
    fn precision_parses_and_rejects_garbage() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("FP32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert_eq!(Precision::parse(" i8 ").unwrap(), Precision::Int8);
        assert!(Precision::parse("bf16").is_err());
        assert!("int8".parse::<Precision>().is_ok());
        assert!("garbage".parse::<Precision>().is_err());
    }

    #[test]
    fn int8_tier_is_deterministic_and_self_consistent() {
        // The relaxed tier gives up bit-exactness vs f32, not determinism:
        // within int8, greedy decode repeats exactly and the KV decode
        // path still chains bit-exactly into re-prefill.
        let mut rt = toy_runtime();
        rt.set_precision(Precision::Int8);
        assert_eq!(rt.precision(), Precision::Int8);
        let prompt = vec![3u32, 8, 2];
        let a = rt.generate(&[prompt.clone()].to_vec(), 4).unwrap();
        let b = rt.generate(&[prompt.clone()].to_vec(), 4).unwrap();
        assert_eq!(a, b, "int8 greedy decode must be deterministic");
        assert!(a[0].iter().all(|&t| t < 16));
        let mut longer = prompt.clone();
        longer.push(a[0][0]);
        let again = rt.generate(&[longer].to_vec(), 2).unwrap();
        assert_eq!(again[0][0], a[0][1], "int8 KV decode must match re-prefill");
    }

    #[test]
    fn precision_roundtrip_restores_f32_bits() {
        // Entering and leaving int8 must leave the f32 path untouched —
        // the f32 parameters are never modified, only mirrored.
        let rt = toy_runtime();
        let tokens: Vec<i32> = vec![3, 8, 2, 1, 0, 0, 0, 0, 9, 4, 4, 7, 1, 0, 0, 0];
        let before = rt.prefill(2, &tokens).unwrap();
        let mut rt2 = toy_runtime();
        rt2.set_precision(Precision::Int8);
        rt2.set_precision(Precision::F32);
        let after = rt2.prefill(2, &tokens).unwrap();
        assert!(before
            .logits
            .iter()
            .zip(&after.logits)
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn quant_counters_track_int8_work_only() {
        let rt = toy_runtime();
        rt.generate(&[vec![1u32, 2, 3]].to_vec(), 3).unwrap();
        let s = rt.stats();
        assert_eq!(s.quant_gemm_calls, 0, "f32 path must not count quant work");
        assert_eq!(s.quant_bytes_saved, 0);

        let mut rtq = toy_runtime();
        rtq.set_precision(Precision::Int8);
        rtq.generate(&[vec![1u32, 2, 3]].to_vec(), 3).unwrap();
        let q = rtq.stats();
        // Toy model: 2 layers x 6 GEMMs + 1 logits job per call, 3 calls
        // (1 prefill + 2 decode steps), one active row each.
        assert_eq!(q.quant_gemm_calls, 3 * (2 * 6 + 1));
        // Bytes: per call, row GEMMs 2 layers * 3 * (4*8*8 + 2*8*16) and
        // one logits job 3 * 16 * 8.
        let per_call = 2 * 3 * (4 * 8 * 8 + 2 * 8 * 16) + 3 * 16 * 8;
        assert_eq!(q.quant_bytes_saved, 3 * per_call as u64);
        rtq.reset_stats();
        assert_eq!(rtq.stats(), RtStats::default());
    }

    #[test]
    fn stats_accumulate() {
        let rt = toy_runtime();
        assert_eq!(rt.stats(), RtStats::default());
        rt.generate(&[vec![1u32, 2, 3]].to_vec(), 3).unwrap();
        let s = rt.stats();
        assert_eq!(s.prefill_calls, 1);
        assert_eq!(s.prefill_tokens, 8); // 1 row x padded seq 8
        assert_eq!(s.decode_calls, 2);
        assert_eq!(s.decode_tokens, 2);
        rt.reset_stats();
        assert_eq!(rt.stats(), RtStats::default());
    }

    #[test]
    fn error_paths() {
        let rt = toy_runtime();
        assert!(rt.prefill(1, &[0i32; 7]).is_err(), "bad token count");
        assert!(rt.prefill(3, &[0i32; 24]).is_err(), "no batch-3 artifact");
        assert!(rt.prefill(1, &[99i32; 8]).is_err(), "token outside vocab");
        assert!(
            rt.prefill_last(1, &[0i32; 8], &[8], None).is_err(),
            "last position outside window"
        );
        assert!(rt.generate(&[vec![1u32; 20]].to_vec(), 2).is_err(), "prompt too long");
        assert!(rt.generate(&[vec![1u32; 4]].to_vec(), 100).is_err(), "beyond headroom");
    }
}
