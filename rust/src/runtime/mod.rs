//! TinyLM runtime: load and execute the AOT-compiled TinyLM artifacts.
//!
//! The AOT bridge's Rust half (DESIGN.md §4): `python/compile/aot.py` wrote
//! HLO text plus `params.bin`/`manifest.json`; this module parses the
//! manifest (with the in-repo JSON parser), loads the parameters, and
//! exposes typed prefill/decode calls. No Python anywhere near this path.
//!
//! Execution backend: a pure-Rust CPU interpreter of the TinyLM forward
//! pass (the architecture `python/compile/model.py` lowers: 4-layer RoPE
//! transformer, RMSNorm, GELU MLP, causal attention, paged-style KV cache
//! [L, B, Smax, H, D]). The build environment vendors no `xla`/PJRT crate
//! (DESIGN.md §2 offline-dependency substitutions), so the HLO files are
//! carried as artifacts-of-record while compute runs here. The manifest's
//! artifact entries still define which (batch, seq) shapes exist — calls
//! for unlisted batch sizes fail exactly as the compiled path did, keeping
//! `RealEngine`'s batch-padding logic honest.
//!
//! Numerical contract (rust/tests/runtime_e2e.rs): greedy decode is
//! deterministic, batch rows are independent, and the KV-cache decode path
//! is bit-exact with re-prefill — prefill and decode share the same
//! accumulation-ordered helpers below, so the last property holds exactly.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::json::{parse, Json};
use crate::util::err::{Error, Result};

/// Dense row-major f32 tensor (parameters, KV caches).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Host-side KV tensor handed back to the decode loop ([L, B, Smax, H, D]).
pub type DeviceTensor = Tensor;

/// Model hyper-parameters from the manifest.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub page_size: usize,
}

#[derive(Debug, Clone)]
struct ParamEntry {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    numel: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub seq: usize,
    pub file: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub cfg: ModelCfg,
    params: Vec<ParamEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::msg(format!("reading manifest in {dir:?} (run `make artifacts`): {e}"))
        })?;
        let j = parse(&text).map_err(|e| Error::msg(format!("manifest.json: {e}")))?;
        let c = &j["config"];
        let need = |v: &Json, k: &str| -> Result<usize> {
            v[k].as_usize().ok_or_else(|| Error::msg(format!("manifest config missing {k}")))
        };
        let cfg = ModelCfg {
            vocab: need(c, "vocab")?,
            d_model: need(c, "d_model")?,
            n_layers: need(c, "n_layers")?,
            n_heads: need(c, "n_heads")?,
            head_dim: need(c, "head_dim")?,
            max_seq: need(c, "max_seq")?,
            page_size: need(c, "page_size")?,
        };
        let params = j["params"]
            .as_arr()
            .ok_or_else(|| Error::msg("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p["name"].as_str().unwrap_or_default().to_string(),
                    shape: p["shape"]
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p["offset"].as_usize().ok_or_else(|| Error::msg("offset"))?,
                    numel: p["numel"].as_usize().ok_or_else(|| Error::msg("numel"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j["artifacts"]
            .as_arr()
            .ok_or_else(|| Error::msg("manifest missing artifacts"))?
            .iter()
            .map(|a| ArtifactEntry {
                name: a["name"].as_str().unwrap_or_default().to_string(),
                kind: a["kind"].as_str().unwrap_or_default().to_string(),
                batch: a["batch"].as_usize().unwrap_or(0),
                seq: a["seq"].as_usize().unwrap_or(0),
                file: a["file"].as_str().unwrap_or_default().to_string(),
            })
            .collect();
        Ok(Manifest { cfg, params, artifacts, dir: dir.to_path_buf() })
    }

    /// Read params.bin into per-parameter f32 tensors (manifest order).
    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        let mut f = std::fs::File::open(self.dir.join("params.bin"))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        let total: usize = self.params.iter().map(|p| p.numel).sum();
        if bytes.len() != total * 4 {
            return Err(Error::msg(format!(
                "params.bin is {} bytes, manifest wants {}",
                bytes.len(),
                total * 4
            )));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        self.params
            .iter()
            .map(|p| {
                let shape_elems: usize = p.shape.iter().product();
                if p.offset + p.numel > floats.len() || shape_elems != p.numel {
                    return Err(Error::msg(format!("param {} malformed or out of bounds", p.name)));
                }
                Ok(Tensor {
                    dims: p.shape.clone(),
                    data: floats[p.offset..p.offset + p.numel].to_vec(),
                })
            })
            .collect()
    }

    /// Name of the i-th parameter (manifest order).
    fn param_name(&self, i: usize) -> &str {
        &self.params[i].name
    }
}

/// Output of one prefill call.
pub struct PrefillOut {
    /// Logits for every position: [B][S][V] flattened per row.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// KV caches carried between calls by the decode loop.
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl PrefillOut {
    /// Logits row for batch `b` at position `pos`.
    pub fn logits_at(&self, b: usize, pos: usize) -> &[f32] {
        let start = (b * self.seq + pos) * self.vocab;
        &self.logits[start..start + self.vocab]
    }

    pub fn argmax_at(&self, b: usize, pos: usize) -> u32 {
        argmax(self.logits_at(b, pos))
    }
}

/// Output of one decode step.
pub struct DecodeOut {
    /// [B][V] logits.
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl DecodeOut {
    pub fn logits_of(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn argmax_of(&self, b: usize) -> u32 {
        argmax(self.logits_of(b))
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

// --------------------------------------------------------- math helpers

fn rms_norm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = x.len();
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / (ss / d as f32 + 1e-5).sqrt();
    for i in 0..d {
        out[i] = x[i] * inv * g[i];
    }
}

/// out[n] = x[k] @ w[k, n] (w row-major [k, n]).
fn matvec(x: &[f32], w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (i, &xi) in x.iter().enumerate().take(k) {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for j in 0..n {
            out[j] += xi * row[j];
        }
    }
}

/// In-place rotary embedding of one head vector at absolute position `pos`.
fn rope(v: &mut [f32], pos: usize, base: f32) {
    let d = v.len();
    let half = d / 2;
    for j in 0..half {
        let freq = base.powf(-(j as f32) / half as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let x1 = v[j];
        let x2 = v[j + half];
        v[j] = x1 * cos - x2 * sin;
        v[j + half] = x1 * sin + x2 * cos;
    }
}

/// tanh-approximated GELU (jax.nn.gelu's default form).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

/// Attention for one (batch row, head, query position): softmax over cache
/// positions `0..kv_len`, accumulating in ascending-j order so prefill and
/// decode produce bit-identical sums.
#[allow(clippy::too_many_arguments)]
fn attend_one(
    q: &[f32],
    k_cache: &Tensor,
    v_cache: &Tensor,
    layer: usize,
    b: usize,
    head: usize,
    kv_len: usize,
    cfg: &ModelCfg,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let hd = cfg.head_dim;
    let scale = 1.0 / (hd as f32).sqrt();
    let stride_b = cfg.max_seq * cfg.n_heads * hd;
    let base = (layer * k_cache.dims[1] + b) * stride_b;
    scores.clear();
    let mut max_s = f32::NEG_INFINITY;
    for j in 0..kv_len {
        let off = base + j * cfg.n_heads * hd + head * hd;
        let kj = &k_cache.data[off..off + hd];
        let mut dot = 0.0f32;
        for d in 0..hd {
            dot += q[d] * kj[d];
        }
        let s = dot * scale;
        scores.push(s);
        if s > max_s {
            max_s = s;
        }
    }
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - max_s).exp();
        denom += *s;
    }
    for o in out.iter_mut().take(hd) {
        *o = 0.0;
    }
    for (j, &p) in scores.iter().enumerate() {
        let w = p / denom;
        let off = base + j * cfg.n_heads * hd + head * hd;
        let vj = &v_cache.data[off..off + hd];
        for d in 0..hd {
            out[d] += w * vj[d];
        }
    }
}

// ------------------------------------------------------------ parameters

struct LayerParams {
    ln1: Tensor,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ln2: Tensor,
    w_in: Tensor,
    w_out: Tensor,
}

struct TinyLmParams {
    embed: Tensor, // [V, Dm]
    layers: Vec<LayerParams>,
    ln_f: Tensor, // [Dm]
    d_ff: usize,
}

impl TinyLmParams {
    fn from_manifest(manifest: &Manifest, tensors: Vec<Tensor>) -> Result<TinyLmParams> {
        let mut by_name: BTreeMap<String, Tensor> = BTreeMap::new();
        for (i, t) in tensors.into_iter().enumerate() {
            by_name.insert(manifest.param_name(i).to_string(), t);
        }
        let mut take = |name: &str| -> Result<Tensor> {
            by_name.remove(name).ok_or_else(|| Error::msg(format!("manifest missing param {name}")))
        };
        let embed = take("embed")?;
        let mut layers = Vec::new();
        for i in 0..manifest.cfg.n_layers {
            layers.push(LayerParams {
                ln1: take(&format!("l{i}.ln1"))?,
                wq: take(&format!("l{i}.wq"))?,
                wk: take(&format!("l{i}.wk"))?,
                wv: take(&format!("l{i}.wv"))?,
                wo: take(&format!("l{i}.wo"))?,
                ln2: take(&format!("l{i}.ln2"))?,
                w_in: take(&format!("l{i}.w_in"))?,
                w_out: take(&format!("l{i}.w_out"))?,
            });
        }
        let ln_f = take("ln_f")?;
        let d_ff = layers
            .first()
            .and_then(|l| l.w_in.dims.get(1).copied())
            .ok_or_else(|| Error::msg("cannot infer d_ff from l0.w_in"))?;
        Ok(TinyLmParams { embed, layers, ln_f, d_ff })
    }
}

// --------------------------------------------------------------- runtime

/// The loaded model: parameters + the artifact shape table.
pub struct TinyLmRuntime {
    pub cfg: ModelCfg,
    params: TinyLmParams,
    /// batch -> prefill sequence capacity, from the manifest's artifacts.
    prefill: BTreeMap<usize, usize>,
    /// Decode batch sizes with a compiled artifact.
    decode: BTreeSet<usize>,
}

impl TinyLmRuntime {
    /// Load the manifest + parameters in `dir`.
    pub fn load(dir: &Path) -> Result<TinyLmRuntime> {
        let manifest = Manifest::load(dir)?;
        let tensors = manifest.load_params()?;
        let params = TinyLmParams::from_manifest(&manifest, tensors)?;

        let mut prefill = BTreeMap::new();
        let mut decode = BTreeSet::new();
        for a in &manifest.artifacts {
            match a.kind.as_str() {
                "prefill" => {
                    if a.seq == 0 || a.seq > manifest.cfg.max_seq {
                        return Err(Error::msg(format!(
                            "prefill artifact {} has seq {} outside (0, max_seq {}]",
                            a.name, a.seq, manifest.cfg.max_seq
                        )));
                    }
                    prefill.insert(a.batch, a.seq);
                }
                "decode" => {
                    decode.insert(a.batch);
                }
                k => return Err(Error::msg(format!("unknown artifact kind {k}"))),
            }
        }
        if prefill.is_empty() || decode.is_empty() {
            return Err(Error::msg(format!(
                "artifacts incomplete: {} prefill, {} decode",
                prefill.len(),
                decode.len()
            )));
        }
        Ok(TinyLmRuntime { cfg: manifest.cfg, params, prefill, decode })
    }

    /// Available prefill batch sizes.
    pub fn prefill_batches(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    /// Available decode batch sizes.
    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.iter().copied().collect()
    }

    /// Prefill sequence capacity for batch `b`.
    pub fn prefill_seq(&self, batch: usize) -> Option<usize> {
        self.prefill.get(&batch).copied()
    }

    fn kv_index(&self, layer: usize, batch: usize, b: usize, pos: usize) -> usize {
        ((layer * batch + b) * self.cfg.max_seq + pos) * self.cfg.n_heads * self.cfg.head_dim
    }

    /// One transformer block position: given the normalized input's q/k/v
    /// rows already written into the cache at `pos`, finish attention + MLP
    /// and update the residual `x` in place.
    #[allow(clippy::too_many_arguments)]
    fn block_tail(
        &self,
        lp: &LayerParams,
        layer: usize,
        b: usize,
        pos: usize,
        kv_len: usize,
        q_row: &[f32],
        k_cache: &Tensor,
        v_cache: &Tensor,
        x: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let cfg = &self.cfg;
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        for head in 0..h {
            attend_one(
                &q_row[head * hd..(head + 1) * hd],
                k_cache,
                v_cache,
                layer,
                b,
                head,
                kv_len.max(pos + 1).min(cfg.max_seq),
                cfg,
                &mut scratch.scores,
                &mut scratch.attn[head * hd..(head + 1) * hd],
            );
        }
        matvec(&scratch.attn, &lp.wo.data, dm, dm, &mut scratch.proj);
        for d in 0..dm {
            x[d] += scratch.proj[d];
        }
        rms_norm(x, &lp.ln2.data, &mut scratch.xn);
        matvec(&scratch.xn, &lp.w_in.data, dm, self.params.d_ff, &mut scratch.ff);
        for v in scratch.ff.iter_mut() {
            *v = gelu(*v);
        }
        matvec(&scratch.ff, &lp.w_out.data, self.params.d_ff, dm, &mut scratch.proj);
        for d in 0..dm {
            x[d] += scratch.proj[d];
        }
    }

    fn final_logits(&self, x: &[f32], scratch: &mut Scratch, out: &mut [f32]) {
        rms_norm(x, &self.params.ln_f.data, &mut scratch.xn);
        // logits = xn @ embed.T : dot against each vocab row.
        let dm = self.cfg.d_model;
        for (t, o) in out.iter_mut().enumerate() {
            let row = &self.params.embed.data[t * dm..(t + 1) * dm];
            let mut dot = 0.0f32;
            for d in 0..dm {
                dot += scratch.xn[d] * row[d];
            }
            *o = dot;
        }
    }

    /// Run prefill over `tokens` (row-major [B, S], pre-padded to the
    /// artifact's S; entries are token ids < vocab).
    pub fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<PrefillOut> {
        let seq = *self
            .prefill
            .get(&batch)
            .ok_or_else(|| Error::msg(format!("no prefill artifact for batch {batch}")))?;
        if tokens.len() != batch * seq {
            return Err(Error::msg(format!("tokens len {} != {batch}x{seq}", tokens.len())));
        }
        let cfg = self.cfg.clone();
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        let mut k_cache =
            Tensor::zeros(vec![cfg.n_layers, batch, cfg.max_seq, h, hd]);
        let mut v_cache = k_cache.clone();
        let mut logits = vec![0.0f32; batch * seq * cfg.vocab];
        let mut scratch = Scratch::new(dm, self.params.d_ff, h * hd);

        for b in 0..batch {
            // Residual stream for every position of this row.
            // Out-of-vocab ids are caller bugs — fail loudly rather than
            // embed a clamped stand-in and generate plausible garbage.
            let mut xs: Vec<Vec<f32>> = Vec::with_capacity(seq);
            for s in 0..seq {
                let raw = tokens[b * seq + s];
                if raw < 0 || raw as usize >= cfg.vocab {
                    return Err(Error::msg(format!(
                        "token id {raw} at [{b},{s}] outside vocab {}",
                        cfg.vocab
                    )));
                }
                let tok = raw as usize;
                xs.push(self.params.embed.data[tok * dm..(tok + 1) * dm].to_vec());
            }
            for (layer, lp) in self.params.layers.iter().enumerate() {
                // Project + rope + write the whole row's k/v first so
                // attention at position i sees keys 0..=i.
                let mut q_rows: Vec<Vec<f32>> = Vec::with_capacity(seq);
                for (s, x) in xs.iter().enumerate() {
                    rms_norm(x, &lp.ln1.data, &mut scratch.xn);
                    let mut q = vec![0.0f32; dm];
                    matvec(&scratch.xn, &lp.wq.data, dm, dm, &mut q);
                    matvec(&scratch.xn, &lp.wk.data, dm, dm, &mut scratch.proj);
                    let koff = self.kv_index(layer, batch, b, s);
                    k_cache.data[koff..koff + dm].copy_from_slice(&scratch.proj);
                    matvec(&scratch.xn, &lp.wv.data, dm, dm, &mut scratch.proj);
                    v_cache.data[koff..koff + dm].copy_from_slice(&scratch.proj);
                    for head in 0..h {
                        rope(&mut q[head * hd..(head + 1) * hd], s, 10_000.0);
                        rope(&mut k_cache.data[koff + head * hd..koff + (head + 1) * hd], s, 10_000.0);
                    }
                    q_rows.push(q);
                }
                for (s, x) in xs.iter_mut().enumerate() {
                    self.block_tail(
                        lp, layer, b, s, s + 1, &q_rows[s], &k_cache, &v_cache, x, &mut scratch,
                    );
                }
            }
            for (s, x) in xs.iter().enumerate() {
                let out = &mut logits[(b * seq + s) * cfg.vocab..(b * seq + s + 1) * cfg.vocab];
                self.final_logits(x, &mut scratch, out);
            }
        }
        Ok(PrefillOut { logits, batch, seq, vocab: cfg.vocab, k: k_cache, v: v_cache })
    }

    /// One decode step: `token[b]` written at `pos[b]`, attending to
    /// positions <= pos. KV buffers are consumed by value and handed back
    /// in the output — the per-token hot path never copies the cache.
    pub fn decode(
        &self,
        batch: usize,
        token: &[i32],
        pos: &[i32],
        k: DeviceTensor,
        v: DeviceTensor,
    ) -> Result<DecodeOut> {
        if !self.decode.contains(&batch) {
            return Err(Error::msg(format!("no decode artifact for batch {batch}")));
        }
        if token.len() != batch || pos.len() != batch {
            return Err(Error::msg("decode arg arity mismatch"));
        }
        let cfg = self.cfg.clone();
        let (h, hd, dm) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
        if k.dims != [cfg.n_layers, batch, cfg.max_seq, h, hd] {
            return Err(Error::msg(format!("k cache dims {:?} unexpected", k.dims)));
        }
        if v.dims != k.dims {
            return Err(Error::msg(format!("v cache dims {:?} != k dims {:?}", v.dims, k.dims)));
        }
        let mut k_cache = k;
        let mut v_cache = v;
        let mut logits = vec![0.0f32; batch * cfg.vocab];
        let mut scratch = Scratch::new(dm, self.params.d_ff, h * hd);

        for b in 0..batch {
            if pos[b] < 0 || pos[b] as usize >= cfg.max_seq {
                return Err(Error::msg(format!("decode position {} beyond cache", pos[b])));
            }
            let p = pos[b] as usize;
            if token[b] < 0 || token[b] as usize >= cfg.vocab {
                return Err(Error::msg(format!(
                    "decode token id {} outside vocab {}",
                    token[b], cfg.vocab
                )));
            }
            let tok = token[b] as usize;
            let mut x: Vec<f32> = self.params.embed.data[tok * dm..(tok + 1) * dm].to_vec();
            for (layer, lp) in self.params.layers.iter().enumerate() {
                rms_norm(&x, &lp.ln1.data, &mut scratch.xn);
                let mut q = vec![0.0f32; dm];
                matvec(&scratch.xn, &lp.wq.data, dm, dm, &mut q);
                matvec(&scratch.xn, &lp.wk.data, dm, dm, &mut scratch.proj);
                let koff = self.kv_index(layer, batch, b, p);
                k_cache.data[koff..koff + dm].copy_from_slice(&scratch.proj);
                matvec(&scratch.xn, &lp.wv.data, dm, dm, &mut scratch.proj);
                v_cache.data[koff..koff + dm].copy_from_slice(&scratch.proj);
                for head in 0..h {
                    rope(&mut q[head * hd..(head + 1) * hd], p, 10_000.0);
                    rope(&mut k_cache.data[koff + head * hd..koff + (head + 1) * hd], p, 10_000.0);
                }
                self.block_tail(
                    lp, layer, b, p, p + 1, &q, &k_cache, &v_cache, &mut x, &mut scratch,
                );
            }
            self.final_logits(&x, &mut scratch, &mut logits[b * cfg.vocab..(b + 1) * cfg.vocab]);
        }
        Ok(DecodeOut { logits, vocab: cfg.vocab, k: k_cache, v: v_cache })
    }

    /// Greedy-generate `steps` tokens for a batch of prompts (lengths may
    /// differ; prompts are padded to the prefill S). Returns per-row
    /// generated token ids. The workhorse of `RealEngine` / serve_e2e.
    pub fn generate(&self, prompts: &[Vec<u32>], steps: usize) -> Result<Vec<Vec<u32>>> {
        let batch = prompts.len();
        let seq = *self
            .prefill
            .get(&batch)
            .ok_or_else(|| Error::msg(format!("no prefill artifact for batch {batch}")))?;
        let max_new = self.cfg.max_seq - seq;
        if steps > max_new {
            return Err(Error::msg(format!("steps {steps} exceeds cache headroom {max_new}")));
        }
        let mut tokens = vec![0i32; batch * seq];
        for (b, p) in prompts.iter().enumerate() {
            if p.len() > seq {
                return Err(Error::msg(format!("prompt {b} longer than prefill window {seq}")));
            }
            for (s, &t) in p.iter().enumerate() {
                tokens[b * seq + s] = t as i32;
            }
        }
        let pre = self.prefill(batch, &tokens)?;
        let mut cur: Vec<i32> = (0..batch)
            .map(|b| pre.argmax_at(b, prompts[b].len().saturating_sub(1)) as i32)
            .collect();
        let mut k = pre.k;
        let mut v = pre.v;
        let mut out: Vec<Vec<u32>> = cur.iter().map(|&t| vec![t as u32]).collect();
        // Decode continues each row at its true length.
        let mut pos: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        for _ in 1..steps {
            let d = self.decode(batch, &cur, &pos, k, v)?;
            for b in 0..batch {
                cur[b] = d.argmax_of(b) as i32;
                out[b].push(cur[b] as u32);
                pos[b] += 1;
            }
            k = d.k;
            v = d.v;
        }
        Ok(out)
    }
}

/// Reused per-call work buffers.
struct Scratch {
    xn: Vec<f32>,
    proj: Vec<f32>,
    attn: Vec<f32>,
    ff: Vec<f32>,
    scores: Vec<f32>,
}

impl Scratch {
    fn new(dm: usize, d_ff: usize, attn_dim: usize) -> Scratch {
        Scratch {
            xn: vec![0.0; dm],
            proj: vec![0.0; dm],
            attn: vec![0.0; attn_dim],
            ff: vec![0.0; d_ff],
            scores: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny in-memory runtime (2 layers, vocab 16) for interpreter checks —
    /// no artifacts needed.
    fn toy_runtime() -> TinyLmRuntime {
        let cfg = ModelCfg {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            max_seq: 12,
            page_size: 4,
        };
        let mut rng = crate::util::Rng::new(7);
        let mut mk = |dims: Vec<usize>, norm: bool| {
            let n: usize = dims.iter().product();
            let fan_in = dims[0] as f64;
            let data: Vec<f32> = (0..n)
                .map(|_| {
                    if norm {
                        1.0
                    } else {
                        (rng.normal() / fan_in.sqrt()) as f32
                    }
                })
                .collect();
            Tensor { dims, data }
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                ln1: mk(vec![8], true),
                wq: mk(vec![8, 8], false),
                wk: mk(vec![8, 8], false),
                wv: mk(vec![8, 8], false),
                wo: mk(vec![8, 8], false),
                ln2: mk(vec![8], true),
                w_in: mk(vec![8, 16], false),
                w_out: mk(vec![16, 8], false),
            })
            .collect();
        let params = TinyLmParams {
            embed: mk(vec![16, 8], false),
            layers,
            ln_f: mk(vec![8], true),
            d_ff: 16,
        };
        TinyLmRuntime {
            cfg,
            params,
            prefill: [(1usize, 8usize), (2, 8)].into_iter().collect(),
            decode: [1usize, 2].into_iter().collect(),
        }
    }

    #[test]
    fn generate_is_deterministic_and_in_vocab() {
        let rt = toy_runtime();
        let prompts = vec![vec![1u32, 2, 3]];
        let a = rt.generate(&prompts, 4).unwrap();
        let b = rt.generate(&prompts, 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 4);
        assert!(a[0].iter().all(|&t| t < 16));
    }

    #[test]
    fn batch_rows_independent() {
        let rt = toy_runtime();
        let solo = rt.generate(&[vec![5u32, 6, 7]].to_vec(), 3).unwrap();
        let batch = rt.generate(&vec![vec![5u32, 6, 7], vec![9u32, 1]], 3).unwrap();
        assert_eq!(batch[0], solo[0]);
    }

    #[test]
    fn decode_matches_re_prefill() {
        // The KV-cache decode path must chain bit-exactly into prefill: the
        // second generated token equals a fresh prefill of prompt+token1.
        let rt = toy_runtime();
        let prompt = vec![3u32, 8, 2];
        let gen = rt.generate(&[prompt.clone()].to_vec(), 3).unwrap();
        let mut longer = prompt.clone();
        longer.push(gen[0][0]);
        let gen2 = rt.generate(&[longer].to_vec(), 2).unwrap();
        assert_eq!(gen2[0][0], gen[0][1]);
    }

    #[test]
    fn error_paths() {
        let rt = toy_runtime();
        assert!(rt.prefill(1, &[0i32; 7]).is_err(), "bad token count");
        assert!(rt.prefill(3, &[0i32; 24]).is_err(), "no batch-3 artifact");
        assert!(rt.generate(&[vec![1u32; 20]].to_vec(), 2).is_err(), "prompt too long");
        assert!(rt.generate(&[vec![1u32; 4]].to_vec(), 100).is_err(), "beyond headroom");
    }
}
