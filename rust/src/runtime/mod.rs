//! PJRT runtime: load and execute the AOT-compiled TinyLM artifacts.
//!
//! The AOT bridge's Rust half (DESIGN.md §4): `python/compile/aot.py` wrote
//! HLO *text* plus `params.bin`/`manifest.json`; this module parses the
//! manifest (with the in-repo JSON parser), compiles each HLO module on the
//! PJRT CPU client, uploads the parameters **once** as device buffers, and
//! exposes typed prefill/decode calls. No Python anywhere near this path.
//!
//! SAFETY NOTE: only the literal-arg `execute` path is used — the crate's
//! `buffer_from_host_literal` starts an async H2D copy it never awaits,
//! which intermittently SIGSEGVs / trips `pointer_size > 0` checks when the
//! source literal is dropped or the compiler runs concurrently. With the
//! awaited literal path the runtime is stable including across threads
//! (stress-tested; see rust/tests/runtime_e2e.rs).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Host-side tensor handed back to the decode loop.
///
/// NOTE: the `xla` crate exposes a buffer-arg `execute_b` plus
/// `buffer_from_host_literal`, which would keep KV on device between steps —
/// but `buffer_from_host_literal` starts an asynchronous H2D copy and never
/// awaits it, and in this xla_extension build even pinned-source uploads
/// intermittently corrupt compiler state (SIGSEGV / `pointer_size > 0`
/// check failures). The literal-arg `execute` path awaits every transfer in
/// the C wrapper and is the only reliable one, so KV rides host literals.
pub type DeviceTensor = Literal;

use crate::json::{parse, Json};

/// Model hyper-parameters from the manifest.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub page_size: usize,
}

#[derive(Debug, Clone)]
struct ParamEntry {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    numel: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub seq: usize,
    pub file: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub cfg: ModelCfg,
    params: Vec<ParamEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let c = &j["config"];
        let need = |v: &Json, k: &str| -> Result<usize> {
            v[k].as_usize().ok_or_else(|| anyhow!("manifest config missing {k}"))
        };
        let cfg = ModelCfg {
            vocab: need(c, "vocab")?,
            d_model: need(c, "d_model")?,
            n_layers: need(c, "n_layers")?,
            n_heads: need(c, "n_heads")?,
            head_dim: need(c, "head_dim")?,
            max_seq: need(c, "max_seq")?,
            page_size: need(c, "page_size")?,
        };
        let params = j["params"]
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p["name"].as_str().unwrap_or_default().to_string(),
                    shape: p["shape"]
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p["offset"].as_usize().ok_or_else(|| anyhow!("offset"))?,
                    numel: p["numel"].as_usize().ok_or_else(|| anyhow!("numel"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j["artifacts"]
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| ArtifactEntry {
                name: a["name"].as_str().unwrap_or_default().to_string(),
                kind: a["kind"].as_str().unwrap_or_default().to_string(),
                batch: a["batch"].as_usize().unwrap_or(0),
                seq: a["seq"].as_usize().unwrap_or(0),
                file: a["file"].as_str().unwrap_or_default().to_string(),
            })
            .collect();
        Ok(Manifest { cfg, params, artifacts, dir: dir.to_path_buf() })
    }

    /// Read params.bin into per-parameter f32 literals (manifest order).
    pub fn load_params(&self) -> Result<Vec<Literal>> {
        let mut f = std::fs::File::open(self.dir.join("params.bin"))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        let total: usize = self.params.iter().map(|p| p.numel).sum();
        if bytes.len() != total * 4 {
            bail!("params.bin is {} bytes, manifest wants {}", bytes.len(), total * 4);
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        self.params
            .iter()
            .map(|p| {
                let data = &floats[p.offset..p.offset + p.numel];
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping param {}", p.name))
            })
            .collect()
    }
}

/// Output of one prefill call.
pub struct PrefillOut {
    /// Logits for every position: [B][S][V] flattened per row.
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// KV caches stay on device for the decode loop.
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl PrefillOut {
    /// Logits row for batch `b` at position `pos`.
    pub fn logits_at(&self, b: usize, pos: usize) -> &[f32] {
        let start = (b * self.seq + pos) * self.vocab;
        &self.logits[start..start + self.vocab]
    }

    pub fn argmax_at(&self, b: usize, pos: usize) -> u32 {
        argmax(self.logits_at(b, pos))
    }
}

/// Output of one decode step.
pub struct DecodeOut {
    /// [B][V] logits.
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub k: DeviceTensor,
    pub v: DeviceTensor,
}

impl DecodeOut {
    pub fn logits_of(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn argmax_of(&self, b: usize) -> u32 {
        argmax(self.logits_of(b))
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

/// The compiled model: PJRT client + executables + resident parameters.
pub struct TinyLmRuntime {
    pub client: PjRtClient,
    pub cfg: ModelCfg,
    /// Parameters kept as host literals (re-transferred per call by the
    /// awaited literal-arg execute path; see DeviceTensor note).
    params: Vec<Literal>,
    prefill: BTreeMap<usize, (usize, PjRtLoadedExecutable)>,
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
}

impl TinyLmRuntime {
    /// Load every artifact in `dir` and upload parameters to the device.
    pub fn load(dir: &Path) -> Result<TinyLmRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        let params = manifest.load_params()?;

        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for a in &manifest.artifacts {
            let path = dir.join(&a.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            match a.kind.as_str() {
                "prefill" => {
                    prefill.insert(a.batch, (a.seq, exe));
                }
                "decode" => {
                    decode.insert(a.batch, exe);
                }
                k => bail!("unknown artifact kind {k}"),
            }
        }
        if prefill.is_empty() || decode.is_empty() {
            bail!("artifacts incomplete: {} prefill, {} decode", prefill.len(), decode.len());
        }
        Ok(TinyLmRuntime { client, cfg: manifest.cfg, params, prefill, decode })
    }

    /// Available prefill batch sizes.
    pub fn prefill_batches(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    /// Available decode batch sizes.
    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Prefill sequence capacity for batch `b`.
    pub fn prefill_seq(&self, batch: usize) -> Option<usize> {
        self.prefill.get(&batch).map(|(s, _)| *s)
    }

    /// Run prefill over `tokens` (row-major [B, S], pre-padded to the
    /// artifact's S; entries are token ids < vocab).
    pub fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<PrefillOut> {
        let (seq, exe) = self
            .prefill
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill artifact for batch {batch}"))?;
        if tokens.len() != batch * seq {
            bail!("tokens len {} != {batch}x{seq}", tokens.len());
        }
        let tok = Literal::vec1(tokens).reshape(&[batch as i64, *seq as i64])?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&tok);
        let result = exe.execute::<&Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let (logits_l, k, v) = out.to_tuple3()?;
        let logits = logits_l.to_vec::<f32>()?;
        Ok(PrefillOut { logits, batch, seq: *seq, vocab: self.cfg.vocab, k, v })
    }

    /// One decode step: `token[b]` written at `pos[b]`, attending to
    /// positions <= pos. KV buffers are consumed and replaced.
    pub fn decode(
        &self,
        batch: usize,
        token: &[i32],
        pos: &[i32],
        k: &DeviceTensor,
        v: &DeviceTensor,
    ) -> Result<DecodeOut> {
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode artifact for batch {batch}"))?;
        if token.len() != batch || pos.len() != batch {
            bail!("decode arg arity mismatch");
        }
        let tok_l = Literal::vec1(token);
        let pos_l = Literal::vec1(pos);
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&tok_l);
        args.push(&pos_l);
        args.push(k);
        args.push(v);
        let result = exe.execute::<&Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let (logits_l, k2, v2) = out.to_tuple3()?;
        Ok(DecodeOut {
            logits: logits_l.to_vec::<f32>()?,
            vocab: self.cfg.vocab,
            k: k2,
            v: v2,
        })
    }

    /// Greedy-generate `steps` tokens for a batch of prompts (lengths may
    /// differ; prompts are padded to the prefill S). Returns per-row
    /// generated token ids. The workhorse of `RealEngine` / serve_e2e.
    pub fn generate(
        &self,
        prompts: &[Vec<u32>],
        steps: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let batch = prompts.len();
        let (seq, _) = self
            .prefill
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill artifact for batch {batch}"))?;
        let seq = *seq;
        let max_new = self.cfg.max_seq - seq;
        if steps > max_new {
            bail!("steps {steps} exceeds cache headroom {max_new}");
        }
        let mut tokens = vec![0i32; batch * seq];
        for (b, p) in prompts.iter().enumerate() {
            if p.len() > seq {
                bail!("prompt {b} longer than prefill window {seq}");
            }
            for (s, &t) in p.iter().enumerate() {
                tokens[b * seq + s] = t as i32;
            }
        }
        let pre = self.prefill(batch, &tokens)?;
        let mut cur: Vec<i32> = (0..batch)
            .map(|b| pre.argmax_at(b, prompts[b].len().saturating_sub(1)) as i32)
            .collect();
        let mut k = pre.k;
        let mut v = pre.v;
        let mut out: Vec<Vec<u32>> = cur.iter().map(|&t| vec![t as u32]).collect();
        // Decode continues each row at its true length.
        let mut pos: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        for _ in 1..steps {
            let d = self.decode(batch, &cur, &pos, &k, &v)?;
            for b in 0..batch {
                cur[b] = d.argmax_of(b) as i32;
                out[b].push(cur[b] as u32);
                pos[b] += 1;
            }
            k = d.k;
            v = d.v;
        }
        Ok(out)
    }
}
