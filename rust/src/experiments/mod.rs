//! Paper experiments: one submodule per table/figure (DESIGN.md §6).
//!
//! Each experiment exposes a `run_*` returning structured results plus a
//! `render` producing the paper-style table. `benches/` and the `aibrix`
//! CLI both call these, so `cargo bench` regenerates every artifact and
//! `aibrix bench-*` gives the interactive path.

pub mod fig7;
pub mod hetero;
pub mod routing;
pub mod scaling;
pub mod table1;

/// Plain-text table writer (no external deps).
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> TextTable {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_table_renders_aligned() {
        let mut t = TextTable::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "2.25".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
