//! EXP-AS — §3.2.4: LLM-specific autoscaling vs native HPA.
//!
//! Bursty workload against a dynamically scaled fleet with cold-start
//! delays. Paper claim: KPA/APA-style scaling reduces latency 11.5%,
//! increases token throughput 11.4%, and cuts scaling oscillations 33%
//! relative to native HPA.

use super::{fmt_f, TextTable};
use crate::autoscaler::simulate::{run, ScalingReport, ScalingSimConfig};
use crate::autoscaler::{Apa, Hpa, Kpa, Scaler};

pub struct ScalerRow {
    pub name: &'static str,
    pub report: ScalingReport,
}

pub fn run_scaling(cfg: &ScalingSimConfig) -> Vec<ScalerRow> {
    let target = 8.0;
    let (min, max) = (1, 24);
    let mut rows = Vec::new();
    let scalers: Vec<(&'static str, Box<dyn Scaler>)> = vec![
        ("hpa", Box::new(Hpa::new(target, min, max))),
        ("kpa", Box::new(Kpa::new(target, min, max))),
        ("apa", Box::new(Apa::new(target, min, max))),
    ];
    for (name, mut s) in scalers {
        rows.push(ScalerRow { name, report: run(cfg, s.as_mut()) });
    }
    rows
}

pub fn render(rows: &[ScalerRow]) -> String {
    let hpa = rows.iter().find(|r| r.name == "hpa");
    let mut t = TextTable::new(&[
        "Scaler",
        "Completed",
        "Mean lat(ms)",
        "P99 lat(ms)",
        "Tokens/s",
        "ScaleEvents",
        "Oscillations",
        "MeanReplicas",
        "SLO miss",
        "lat vs HPA",
        "tput vs HPA",
    ]);
    for r in rows {
        let (dl, dt) = match hpa {
            Some(h) if r.name != "hpa" => (
                format!(
                    "{:+.1}%",
                    (h.report.latency_ms.mean - r.report.latency_ms.mean)
                        / h.report.latency_ms.mean
                        * 100.0
                ),
                format!(
                    "{:+.1}%",
                    (r.report.token_throughput - h.report.token_throughput)
                        / h.report.token_throughput
                        * 100.0
                ),
            ),
            _ => ("-".into(), "-".into()),
        };
        t.row(vec![
            r.name.to_string(),
            r.report.completed.to_string(),
            fmt_f(r.report.latency_ms.mean, 1),
            fmt_f(r.report.latency_ms.p99, 1),
            fmt_f(r.report.token_throughput, 1),
            r.report.scale_events.to_string(),
            r.report.oscillations.to_string(),
            fmt_f(r.report.mean_replicas, 2),
            fmt_f(r.report.slo_violation_rate * 100.0, 1) + "%",
            dl,
            dt,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SECONDS;
    use crate::workload::ArrivalProcess;

    #[test]
    fn llm_scalers_improve_on_hpa() {
        let mut cfg = ScalingSimConfig::default_burst();
        cfg.duration = 300 * SECONDS;
        cfg.arrival = ArrivalProcess::Burst {
            base: 3.0,
            burst_mult: 6.0,
            start_s: 60.0,
            end_s: 200.0,
        };
        cfg.cold_start_us = 45 * SECONDS;
        let rows = run_scaling(&cfg);
        assert_eq!(rows.len(), 3);
        let hpa = &rows[0].report;
        let apa = &rows[2].report;
        // Direction of the paper's claims.
        assert!(
            apa.latency_ms.mean <= hpa.latency_ms.mean,
            "apa {} vs hpa {}",
            apa.latency_ms.mean,
            hpa.latency_ms.mean
        );
        assert!(apa.completed > 0 && hpa.completed > 0);
    }

    #[test]
    fn renders() {
        let mut cfg = ScalingSimConfig::default_burst();
        cfg.duration = 120 * SECONDS;
        let rows = run_scaling(&cfg);
        let text = render(&rows);
        assert!(text.contains("hpa"));
        assert!(text.contains("Oscillations"));
    }
}
