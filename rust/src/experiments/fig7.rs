//! EXP-F7 — Figure 7: workload-dependent GPU selection.
//!
//! (a) throughput of deepseek-coder-7b workloads on L20 / V100 / A10 across
//! the (input, output) token grid; (b) the per-bin most-cost-efficient GPU
//! map. Paper claim: "most requests favor L20 for cost-efficiency, while
//! those with <200 input and <100 output tokens prefer A10".

use super::{fmt_f, TextTable};
use crate::cluster::GpuKind;
use crate::engine::ModelSpec;
use crate::optimizer::profiles::{ProfileTable, Slo, TokenBin};

pub struct Fig7 {
    pub table: ProfileTable,
    pub gpus: Vec<GpuKind>,
}

pub fn run_fig7() -> Fig7 {
    let gpus = vec![GpuKind::A10, GpuKind::L20, GpuKind::V100];
    let table = ProfileTable::build(&ModelSpec::deepseek_coder_7b(), &gpus, Slo::default());
    Fig7 { table, gpus }
}

/// Figure 7a: throughput (req/s) per GPU per bin.
pub fn render_fig7a(f: &Fig7) -> String {
    let mut t = TextTable::new(&["in", "out", "A10 rps", "L20 rps", "V100 rps"]);
    for bin in TokenBin::grid() {
        let cell = |g: GpuKind| {
            f.table
                .get(g, bin)
                .map(|p| fmt_f(p.max_rps, 2))
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![
            bin.input.to_string(),
            bin.output.to_string(),
            cell(GpuKind::A10),
            cell(GpuKind::L20),
            cell(GpuKind::V100),
        ]);
    }
    t.render()
}

/// Figure 7b: cheapest GPU per bin ($/1k requests in parentheses).
pub fn render_fig7b(f: &Fig7) -> String {
    let mut t = TextTable::new(&["in\\out", "50", "100", "200", "400"]);
    for &input in &[50u32, 100, 200, 400, 800, 1600] {
        let mut cells = vec![input.to_string()];
        for &output in &[50u32, 100, 200, 400] {
            let bin = TokenBin { input, output };
            let cell = match f.table.best_gpu(bin, &f.gpus) {
                Some(g) => {
                    let p = f.table.get(g, bin).unwrap();
                    format!("{} (${:.3})", g.name(), p.dollars_per_kreq)
                }
                None => "-".into(),
            };
            cells.push(cell);
        }
        t.row(cells);
    }
    t.render()
}

/// The paper's crossover summary: fraction of bins preferring each GPU and
/// whether the small-request corner prefers A10.
pub struct CrossoverSummary {
    pub a10_bins: usize,
    pub l20_bins: usize,
    pub v100_bins: usize,
    pub small_corner_is_a10: bool,
}

pub fn crossover(f: &Fig7) -> CrossoverSummary {
    let mut counts = [0usize; 3];
    for bin in TokenBin::grid() {
        match f.table.best_gpu(bin, &f.gpus) {
            Some(GpuKind::A10) => counts[0] += 1,
            Some(GpuKind::L20) => counts[1] += 1,
            Some(GpuKind::V100) => counts[2] += 1,
            _ => {}
        }
    }
    let small = TokenBin { input: 100, output: 50 };
    CrossoverSummary {
        a10_bins: counts[0],
        l20_bins: counts[1],
        v100_bins: counts[2],
        small_corner_is_a10: f.table.best_gpu(small, &f.gpus) == Some(GpuKind::A10),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7b_shape_matches_paper() {
        let f = run_fig7();
        let s = crossover(&f);
        assert!(s.small_corner_is_a10, "small requests must prefer A10");
        assert!(s.l20_bins > 0, "larger workloads must prefer L20");
        assert_eq!(s.v100_bins, 0, "V100 is never cost-optimal for the 7B model");
        // "Most requests favor L20": majority of bins.
        assert!(
            s.l20_bins > s.a10_bins,
            "l20 {} vs a10 {}",
            s.l20_bins,
            s.a10_bins
        );
    }

    #[test]
    fn fig7a_renders_full_grid() {
        let f = run_fig7();
        let a = render_fig7a(&f);
        assert_eq!(a.lines().count(), 2 + TokenBin::grid().len());
        let b = render_fig7b(&f);
        assert!(b.contains("A10") && b.contains("L20"));
    }
}
