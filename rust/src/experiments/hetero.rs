//! EXP-HET — §3.2.7: SLO-driven heterogeneous serving.
//!
//! A ShareGPT + Text2SQL mix is profiled by the load monitor, the GPU
//! optimizer picks fleets for (a) heterogeneous {A10, L20} and (b)
//! homogeneous {L20}, and both fleets serve the same trace. Paper claim:
//! the heterogeneous fleet raises latency ≤20% while staying within SLO and
//! cutting cost ~10%.

use super::{fmt_f, TextTable};
use crate::cluster::{GpuKind, GpuSpec};
use crate::engine::{EngineConfig, ModelSpec};
use crate::gateway::Policy;
use crate::harness::{run, HarnessConfig, RunReport};
use crate::optimizer::ilp::{solve, IlpProblem};
use crate::optimizer::loadmonitor::LoadMonitor;
use crate::optimizer::profiles::{ProfileTable, Slo};
use crate::sim::SimTime;
use crate::util::percentile;
use crate::workload::{ArrivalProcess, Request, ShareGptConfig, ShareGptWorkload, Workload};

/// The evaluation mix: conversational ShareGPT plus Text2SQL-ish requests
/// (short-in/short-out bursts from the SQL side, long chat turns from the
/// other).
pub struct HeteroMix {
    sharegpt: ShareGptWorkload,
    sql: ShareGptWorkload,
    toggle: bool,
    remaining: usize,
}

impl HeteroMix {
    pub fn new(n_requests: usize, seed: u64) -> HeteroMix {
        HeteroMix {
            sharegpt: ShareGptWorkload::new(ShareGptConfig {
                n_requests: n_requests / 2 + 1,
                model: "deepseek-coder-7b".into(),
                seed,
                ..Default::default()
            }),
            sql: ShareGptWorkload::new(ShareGptConfig {
                n_requests: n_requests / 2 + 1,
                prompt_median: 110.0,
                prompt_sigma: 0.5,
                output_median: 40.0,
                output_sigma: 0.5,
                turns_mean: 1.2,
                model: "deepseek-coder-7b".into(),
                seed: seed ^ 0x9E37,
                ..Default::default()
            }),
            toggle: false,
            remaining: n_requests,
        }
    }
}

impl Workload for HeteroMix {
    fn next(&mut self, now: SimTime) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.toggle = !self.toggle;
        if self.toggle {
            self.sharegpt.next(now)
        } else {
            self.sql.next(now)
        }
    }
}

pub struct HeteroParams {
    pub n_requests: usize,
    pub arrival_rps: f64,
    pub seed: u64,
    pub slo: Slo,
    /// TTFT SLO for attainment accounting, ms.
    pub ttft_slo_ms: f64,
    /// Routing policy for the serving runs. The ClusterView plane feeds
    /// `slo` into every snapshot, so `Policy::SloAware` routes on the same
    /// targets the optimizer planned the fleet for.
    pub policy: Policy,
}

impl Default for HeteroParams {
    fn default() -> Self {
        HeteroParams {
            n_requests: 600,
            arrival_rps: 9.0,
            seed: 7,
            slo: Slo::default(),
            ttft_slo_ms: 5_000.0,
            policy: Policy::LeastRequest,
        }
    }
}

pub struct FleetOutcome {
    pub label: String,
    pub counts: Vec<(GpuKind, usize)>,
    pub planned_cost_per_hour: f64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub slo_attainment: f64,
    /// Cost of the fleet over the run's duration, $.
    pub run_cost: f64,
    pub completed: usize,
}

fn demand_from_mix(p: &HeteroParams) -> LoadMonitor {
    let mut monitor = LoadMonitor::new();
    let mut mix = HeteroMix::new(p.n_requests, p.seed);
    let mut n = 0usize;
    while let Some(r) = mix.next(0) {
        monitor.record(r.prompt_len(), r.output_len, 1.0);
        n += 1;
    }
    // Normalize counts into rates: the whole trace arrives over
    // n/arrival_rps seconds.
    let duration_s = n as f64 / p.arrival_rps;
    // LoadMonitor's window is 10s; re-scale by feeding demand() consumers
    // directly — we build the demand vector manually instead.
    let _ = duration_s;
    monitor
}

fn serve(p: &HeteroParams, counts: &[(GpuKind, usize)], label: &str) -> FleetOutcome {
    let model = ModelSpec::deepseek_coder_7b();
    let mut engines = Vec::new();
    let mut node = 0u64;
    for &(gpu, n) in counts {
        for _ in 0..n {
            let mut ec = EngineConfig::new(gpu, model.clone());
            ec.prefix_caching = true;
            engines.push((ec, node));
            node += 1;
        }
    }
    let mut mix = HeteroMix::new(p.n_requests, p.seed);
    // The view carries the experiment's SLO so slo-headroom routing and
    // the optimizer's planning targets agree.
    let view = crate::gateway::ClusterViewConfig { slo: p.slo, ..Default::default() };
    let r: RunReport = run(
        HarnessConfig {
            engines,
            policy: p.policy,
            arrival: ArrivalProcess::Poisson { rate: p.arrival_rps },
            kv_pool: None,
            seed: p.seed,
            deadline: 0,
            closed_loop_clients: 0,
            view,
            chaos: None,
            recovery: Default::default(),
            admission: None,
        },
        &mut mix,
    );
    let lat = r.latency_ms();
    let ttft = r.ttft_ms();
    let within = ttft.iter().filter(|&&t| t <= p.ttft_slo_ms).count();
    let cost_per_hour: f64 = counts
        .iter()
        .map(|&(g, n)| GpuSpec::of(g).dollars_per_hour * n as f64)
        .sum();
    FleetOutcome {
        label: label.to_string(),
        counts: counts.to_vec(),
        planned_cost_per_hour: cost_per_hour,
        mean_latency_ms: crate::util::mean(&lat),
        p99_latency_ms: percentile(&lat, 99.0),
        slo_attainment: if ttft.is_empty() {
            0.0
        } else {
            within as f64 / ttft.len() as f64
        },
        run_cost: cost_per_hour * (r.completion_time_s() / 3600.0),
        completed: r.completions.len(),
    }
}

/// Optimize a fleet for the mix over `gpus`, then serve with it.
pub fn plan_and_serve(p: &HeteroParams, gpus: &[GpuKind], label: &str) -> FleetOutcome {
    let model = ModelSpec::deepseek_coder_7b();
    let profiles = ProfileTable::build(&model, gpus, p.slo);
    let monitor = demand_from_mix(p);
    // Scale bin demand to the arrival rate: counts were recorded over the
    // whole trace; convert to per-second rates.
    let total: f64 = monitor.demand().values().sum();
    let scale = p.arrival_rps / total.max(1e-9);
    let mut demand = monitor.demand();
    for v in demand.values_mut() {
        *v *= scale;
    }
    let problem = IlpProblem::build(&profiles, gpus, &demand, 64);
    let sol = solve(&problem);
    assert!(sol.feasible, "optimizer found no feasible fleet for {label}");
    let counts: Vec<(GpuKind, usize)> = gpus
        .iter()
        .zip(&sol.counts)
        .map(|(&g, &n)| (g, n))
        .filter(|&(_, n)| n > 0)
        .collect();
    serve(p, &counts, label)
}

pub fn run_hetero(p: &HeteroParams) -> (FleetOutcome, FleetOutcome) {
    let hetero = plan_and_serve(p, &[GpuKind::A10, GpuKind::L20], "heterogeneous A10+L20");
    let homo = plan_and_serve(p, &[GpuKind::L20], "homogeneous L20");
    (hetero, homo)
}

pub fn render(hetero: &FleetOutcome, homo: &FleetOutcome) -> String {
    let mut t = TextTable::new(&[
        "Fleet",
        "GPUs",
        "$/hr",
        "Mean lat(ms)",
        "P99 lat(ms)",
        "SLO attain",
        "Run cost($)",
        "Completed",
    ]);
    for o in [homo, hetero] {
        let gpus = o
            .counts
            .iter()
            .map(|(g, n)| format!("{}x{}", n, g.name()))
            .collect::<Vec<_>>()
            .join("+");
        t.row(vec![
            o.label.clone(),
            gpus,
            fmt_f(o.planned_cost_per_hour, 2),
            fmt_f(o.mean_latency_ms, 1),
            fmt_f(o.p99_latency_ms, 1),
            format!("{:.1}%", o.slo_attainment * 100.0),
            fmt_f(o.run_cost, 4),
            o.completed.to_string(),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "\ncost delta: {:+.1}%   latency delta: {:+.1}%\n",
        (hetero.planned_cost_per_hour - homo.planned_cost_per_hour) / homo.planned_cost_per_hour
            * 100.0,
        (hetero.mean_latency_ms - homo.mean_latency_ms) / homo.mean_latency_ms * 100.0,
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> HeteroParams {
        HeteroParams { n_requests: 150, arrival_rps: 6.0, ..Default::default() }
    }

    #[test]
    fn hetero_cheaper_within_slo() {
        let p = quick();
        let (het, homo) = run_hetero(&p);
        assert_eq!(het.completed, p.n_requests);
        assert_eq!(homo.completed, p.n_requests);
        // Paper shape: heterogeneous costs no more than homogeneous…
        assert!(
            het.planned_cost_per_hour <= homo.planned_cost_per_hour,
            "het {} vs homo {}",
            het.planned_cost_per_hour,
            homo.planned_cost_per_hour
        );
        // …and stays within a 20%-ish latency band and high SLO attainment.
        assert!(
            het.mean_latency_ms <= homo.mean_latency_ms * 1.35,
            "latency blowup: het {} homo {}",
            het.mean_latency_ms,
            homo.mean_latency_ms
        );
        assert!(het.slo_attainment > 0.9, "{}", het.slo_attainment);
    }

    #[test]
    fn slo_aware_routing_serves_the_planned_fleet() {
        // ROADMAP follow-on: SLO-driven routing wired into EXP-HET. The
        // slo-headroom scorer routes on the same targets the optimizer
        // planned for; the fleet must still serve everything with solid
        // attainment.
        let p = HeteroParams { policy: Policy::SloAware, ..quick() };
        let het = plan_and_serve(&p, &[GpuKind::A10, GpuKind::L20], "het-slo");
        assert_eq!(het.completed, p.n_requests);
        assert!(het.slo_attainment > 0.75, "{}", het.slo_attainment);
    }

    #[test]
    fn hetero_fleet_actually_mixes() {
        let p = quick();
        let het = plan_and_serve(&p, &[GpuKind::A10, GpuKind::L20], "het");
        // With a mixed small/large workload the optimizer should buy both
        // kinds (or at minimum prefer some A10 for the small bins).
        assert!(het.counts.iter().any(|&(g, _)| g == GpuKind::A10), "{:?}", het.counts);
    }

    #[test]
    fn renders() {
        let p = quick();
        let (het, homo) = run_hetero(&p);
        let text = render(&het, &homo);
        assert!(text.contains("cost delta"));
    }
}
