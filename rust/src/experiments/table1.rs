//! EXP-T1 — Table 1: distributed KV cache on the Bird-SQL workload.
//!
//! 4 engines on 4 A10 nodes serving deepseek-coder-7b, closed-loop clients
//! (the vLLM serving-bench "peak throughput" style), six configurations:
//! {default, chunked prefill, prefix caching} x {with/without the AIBrix
//! distributed KV cache}. Reported columns match the paper: prompt/decode
//! tokens, total & decode throughput, TTFT avg/P99, ITL avg/P99, completion
//! time. Absolute numbers come from the roofline cost model; the claims
//! under test are the *relative* improvements (DESIGN.md §2).

use super::{fmt_f, TextTable};
use crate::cluster::GpuKind;
use crate::engine::{EngineConfig, ModelSpec};
use crate::gateway::Policy;
use crate::harness::{run, HarnessConfig, RunReport};
use crate::kvcache::KvPoolConfig;
use crate::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BaseConfig {
    Default,
    ChunkedPrefill,
    PrefixCaching,
}

impl BaseConfig {
    pub fn label(&self) -> &'static str {
        match self {
            BaseConfig::Default => "vLLM Default",
            BaseConfig::ChunkedPrefill => "vLLM Chunked Prefill",
            BaseConfig::PrefixCaching => "vLLM Prefix Caching",
        }
    }

    pub fn aibrix_label(&self) -> &'static str {
        match self {
            BaseConfig::Default => "AIBrix DistKV + Default",
            BaseConfig::ChunkedPrefill => "AIBrix DistKV + Chunked Prefill",
            BaseConfig::PrefixCaching => "AIBrix DistKV + Prefix Caching",
        }
    }
}

/// One Table 1 row.
pub struct Row {
    pub label: String,
    pub prompt_tokens: u64,
    pub decode_tokens: u64,
    pub total_tput: f64,
    pub decode_tput: f64,
    pub ttft_avg_ms: f64,
    pub ttft_p99_ms: f64,
    pub itl_avg_ms: f64,
    pub itl_p99_ms: f64,
    pub completion_s: f64,
}

impl Row {
    /// Latency stats exclude the cold warmup wave (the first `warmup`
    /// completions — every config pays identical cold-start prefill there,
    /// which would otherwise pin the P99 columns to the same value).
    fn from_report(label: &str, r: &RunReport, warmup: usize) -> Row {
        let cutoff = r.warmup_cutoff(warmup);
        let steady: Vec<f64> = r
            .completions_after(cutoff)
            .iter()
            .map(|c| c.ttft_us() as f64 / 1e3)
            .collect();
        let itl = r.itl_ms_after(cutoff);
        Row {
            label: label.to_string(),
            prompt_tokens: r.served_prompt_tokens(),
            decode_tokens: r.total_decode_tokens,
            total_tput: r.total_throughput(),
            decode_tput: r.decode_throughput(),
            ttft_avg_ms: crate::util::mean(&steady),
            ttft_p99_ms: crate::util::percentile(&steady, 99.0),
            itl_avg_ms: crate::util::mean(&itl),
            itl_p99_ms: crate::util::percentile(&itl, 99.0),
            completion_s: r.completion_time_s(),
        }
    }
}

pub struct Table1Params {
    pub n_engines: usize,
    pub clients: usize,
    pub workload: BirdSqlConfig,
    /// DRAM GiB per node for the distributed pool.
    pub pool_gib_per_node: u64,
    pub seed: u64,
}

impl Default for Table1Params {
    fn default() -> Self {
        Table1Params {
            n_engines: 4,
            clients: 32,
            workload: BirdSqlConfig::default(),
            pool_gib_per_node: 64,
            seed: 2025,
        }
    }
}

fn engine_config(base: BaseConfig) -> EngineConfig {
    let mut ec = EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
    match base {
        BaseConfig::Default => {}
        BaseConfig::ChunkedPrefill => {
            ec.chunked_prefill = true;
            ec.max_batched_tokens = 512;
        }
        BaseConfig::PrefixCaching => {
            ec.prefix_caching = true;
        }
    }
    ec
}

/// Run one (base config, ±dist-KV) cell.
pub fn run_cell(p: &Table1Params, base: BaseConfig, dist_kv: bool) -> RunReport {
    let ec = engine_config(base);
    let engines: Vec<_> = (0..p.n_engines).map(|i| (ec.clone(), i as u64)).collect();
    let kv_pool = if dist_kv {
        Some(KvPoolConfig::new(
            (0..p.n_engines as u64)
                .map(|i| (i, p.pool_gib_per_node << 30))
                .collect(),
            ec.model.kv_bytes_per_token(),
            ec.block_size,
        ))
    } else {
        None
    };
    let mut wl = BirdSqlWorkload::new(p.workload.clone());
    run(
        HarnessConfig {
            engines,
            policy: Policy::LeastRequest,
            arrival: ArrivalProcess::Batch,
            kv_pool,
            seed: p.seed,
            deadline: 0,
            closed_loop_clients: p.clients,
            view: Default::default(),
            chaos: None,
            recovery: Default::default(),
            admission: None,
        },
        &mut wl,
    )
}

/// The full six-row table.
pub fn run_table1(p: &Table1Params) -> Vec<Row> {
    let mut rows = Vec::new();
    let warmup = p.clients * 2;
    for base in [BaseConfig::Default, BaseConfig::ChunkedPrefill, BaseConfig::PrefixCaching] {
        let baseline = run_cell(p, base, false);
        rows.push(Row::from_report(base.label(), &baseline, warmup));
        let aibrix = run_cell(p, base, true);
        rows.push(Row::from_report(base.aibrix_label(), &aibrix, warmup));
    }
    rows
}

pub fn render(rows: &[Row]) -> String {
    let mut t = TextTable::new(&[
        "Method",
        "Prompt",
        "Decode",
        "Tput(tok/s)",
        "DecodeTput",
        "TTFT avg(ms)",
        "TTFT p99(ms)",
        "ITL avg(ms)",
        "ITL p99(ms)",
        "Time(s)",
    ]);
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![
            r.label.clone(),
            r.prompt_tokens.to_string(),
            r.decode_tokens.to_string(),
            fmt_f(r.total_tput, 1),
            fmt_f(r.decode_tput, 2),
            fmt_f(r.ttft_avg_ms, 0),
            fmt_f(r.ttft_p99_ms, 0),
            fmt_f(r.itl_avg_ms, 1),
            fmt_f(r.itl_p99_ms, 1),
            fmt_f(r.completion_s, 1),
        ]);
        // Improvement row after each AIBrix variant, like the paper.
        if i % 2 == 1 {
            let b = &rows[i - 1];
            let pct = |new: f64, old: f64, lower_better: bool| {
                if old == 0.0 || new == 0.0 {
                    return "-".to_string();
                }
                let v = if lower_better {
                    (old - new) / old * 100.0
                } else {
                    (new - old) / old * 100.0
                };
                format!("{v:+.1}%")
            };
            t.row(vec![
                "  Improvement".into(),
                String::new(),
                String::new(),
                pct(r.total_tput, b.total_tput, false),
                pct(r.decode_tput, b.decode_tput, false),
                pct(r.ttft_avg_ms, b.ttft_avg_ms, true),
                pct(r.ttft_p99_ms, b.ttft_p99_ms, true),
                pct(r.itl_avg_ms, b.itl_avg_ms, true),
                pct(r.itl_p99_ms, b.itl_p99_ms, true),
                pct(r.completion_s, b.completion_s, true),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params() -> Table1Params {
        Table1Params {
            n_engines: 2,
            clients: 8,
            workload: BirdSqlConfig {
                n_requests: 60,
                n_schemas: 8,
                schema_tokens_mean: 700,
                question_tokens_mean: 150,
                ..Default::default()
            },
            pool_gib_per_node: 32,
            seed: 1,
        }
    }

    #[test]
    fn dist_kv_improves_prefix_caching_config() {
        // The paper's headline: DistKV + prefix caching beats prefix caching
        // alone on throughput and TTFT.
        let p = quick_params();
        let base = run_cell(&p, BaseConfig::PrefixCaching, false);
        let aibrix = run_cell(&p, BaseConfig::PrefixCaching, true);
        assert_eq!(base.completions.len(), 60);
        assert_eq!(aibrix.completions.len(), 60);
        assert!(
            aibrix.completion_time_s() < base.completion_time_s(),
            "aibrix {} vs base {}",
            aibrix.completion_time_s(),
            base.completion_time_s()
        );
        let ps = aibrix.pool_stats.unwrap();
        assert!(ps.blocks_hit > 0, "pool must contribute hits");
    }

    #[test]
    fn chunked_prefill_tames_itl_tail() {
        let p = quick_params();
        let default = run_cell(&p, BaseConfig::Default, false);
        let chunked = run_cell(&p, BaseConfig::ChunkedPrefill, false);
        let p99_default = crate::util::percentile(&default.itl_ms(), 99.0);
        let p99_chunked = crate::util::percentile(&chunked.itl_ms(), 99.0);
        assert!(
            p99_chunked < p99_default,
            "chunked {p99_chunked} vs default {p99_default}"
        );
    }

    #[test]
    fn table_has_six_rows_and_renders() {
        let p = quick_params();
        let rows = run_table1(&p);
        assert_eq!(rows.len(), 6);
        let text = render(&rows);
        assert!(text.contains("vLLM Default"));
        assert!(text.contains("AIBrix DistKV + Prefix Caching"));
        assert!(text.contains("Improvement"));
    }
}
