//! EXP-RT — §3.2.2 / Figure 3: routing strategy comparison.
//!
//! Mixed workload (prefix-heavy Bird-SQL-like + conversational ShareGPT-
//! like) over 8 prefix-caching engines, Poisson arrivals near saturation.
//! Paper claim: picking a fitting strategy cuts mean latency 19.2% and P99
//! latency 79% (vs naive routing).

use super::{fmt_f, TextTable};
use crate::cluster::GpuKind;
use crate::engine::{EngineConfig, ModelSpec};
use crate::gateway::Policy;
use crate::harness::{run, HarnessConfig};
use crate::sim::SimTime;
use crate::util::percentile;
use crate::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload, Request, Workload};

/// Interleave two workloads (prefix-heavy + conversational shapes).
pub struct MixedWorkload {
    inner: BirdSqlWorkload,
}

impl MixedWorkload {
    pub fn new(n_requests: usize, seed: u64) -> MixedWorkload {
        // Bird-SQL-like with more schemas and longer outputs approximates
        // the mixed agent/chat traffic of the routing evaluation: large
        // shared prefixes with conversational output lengths.
        MixedWorkload {
            inner: BirdSqlWorkload::new(BirdSqlConfig {
                n_requests,
                n_schemas: 24,
                schema_tokens_mean: 900,
                question_tokens_mean: 220,
                output_median: 90.0,
                output_sigma: 0.8,
                zipf_s: 1.0,
                model: "deepseek-coder-7b".into(),
                seed,
            }),
        }
    }
}

impl Workload for MixedWorkload {
    fn next(&mut self, now: SimTime) -> Option<Request> {
        self.inner.next(now)
    }
}

pub struct PolicyRow {
    pub policy: String,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub ttft_mean_ms: f64,
    pub completed: usize,
}

pub struct RoutingParams {
    pub n_engines: usize,
    pub n_requests: usize,
    pub arrival_rps: f64,
    pub seed: u64,
}

impl Default for RoutingParams {
    fn default() -> Self {
        RoutingParams { n_engines: 8, n_requests: 800, arrival_rps: 14.0, seed: 42 }
    }
}

pub fn run_policy(p: &RoutingParams, policy: Policy) -> PolicyRow {
    let mut ec = EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
    ec.prefix_caching = true;
    let engines: Vec<_> = (0..p.n_engines).map(|i| (ec.clone(), i as u64)).collect();
    let mut wl = MixedWorkload::new(p.n_requests, p.seed);
    let r = run(
        HarnessConfig {
            engines,
            policy,
            arrival: ArrivalProcess::Poisson { rate: p.arrival_rps },
            kv_pool: None,
            seed: p.seed,
            deadline: 0,
            closed_loop_clients: 0,
            view: Default::default(),
            chaos: None,
            recovery: Default::default(),
            admission: None,
        },
        &mut wl,
    );
    let lat = r.latency_ms();
    PolicyRow {
        policy: label_for(policy),
        mean_ms: crate::util::mean(&lat),
        p99_ms: percentile(&lat, 99.0),
        ttft_mean_ms: r.ttft_summary().mean,
        completed: r.completions.len(),
    }
}

/// Display label: presets use the paper name; weighted mixes show weights.
fn label_for(policy: Policy) -> String {
    match policy {
        Policy::Weighted(cfg) => {
            let mut parts = Vec::new();
            for (w, name) in [
                (cfg.prefix_affinity, "prefix"),
                (cfg.least_request, "load"),
                (cfg.least_kv_cache, "kv"),
                (cfg.least_latency, "lat"),
                (cfg.throughput, "tps"),
                (cfg.lora_residency, "lora"),
                (cfg.fairness, "fair"),
            ] {
                if w > 0.0 {
                    parts.push(format!("{name}={w:.2}"));
                }
            }
            format!("weighted[{}]", parts.join(","))
        }
        p => p.name().to_string(),
    }
}

/// The §3.2.2 hybrid the closed enum could not express: prefix affinity
/// blended with load spreading.
pub fn hybrid_prefix_load() -> Policy {
    let mut cfg = crate::gateway::PipelineConfig::single("prefix", 0.6);
    cfg.least_request = 0.4;
    Policy::Weighted(cfg)
}

/// All six paper policies, the ClusterView presets (`slo-aware` trades
/// affinity against deadline risk; `session-sticky` pins each schema
/// "session" to a pod — the Bird-SQL generator keys sessions on schemas,
/// so stickiness doubles as prefix locality; `pool-aware` degrades to its
/// load terms without a pool), plus the weighted hybrid — same
/// workload/seed for every row.
pub fn run_routing(p: &RoutingParams) -> Vec<PolicyRow> {
    Policy::extended()
        .into_iter()
        .chain(std::iter::once(hybrid_prefix_load()))
        .map(|pol| run_policy(p, pol))
        .collect()
}

pub fn render(rows: &[PolicyRow]) -> String {
    let baseline = rows
        .iter()
        .find(|r| r.policy == "random")
        .map(|r| (r.mean_ms, r.p99_ms));
    let mut t = TextTable::new(&[
        "Policy",
        "Mean lat(ms)",
        "P99 lat(ms)",
        "TTFT mean(ms)",
        "vs random mean",
        "vs random p99",
        "Completed",
    ]);
    for r in rows {
        let (dm, dp) = match baseline {
            Some((bm, bp)) if r.policy != "random" => (
                format!("{:+.1}%", (bm - r.mean_ms) / bm * 100.0),
                format!("{:+.1}%", (bp - r.p99_ms) / bp * 100.0),
            ),
            _ => ("-".to_string(), "-".to_string()),
        };
        t.row(vec![
            r.policy.to_string(),
            fmt_f(r.mean_ms, 1),
            fmt_f(r.p99_ms, 1),
            fmt_f(r.ttft_mean_ms, 1),
            dm,
            dp,
            r.completed.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> RoutingParams {
        RoutingParams { n_engines: 4, n_requests: 150, arrival_rps: 8.0, seed: 3 }
    }

    #[test]
    fn all_policies_complete_everything() {
        let p = quick();
        for row in run_routing(&p) {
            assert_eq!(row.completed, 150, "{}", row.policy);
            assert!(row.mean_ms > 0.0);
        }
    }

    #[test]
    fn a_fitting_policy_beats_random() {
        // The claim's direction: at least one LLM-aware policy improves both
        // mean and tail over random on the prefix-heavy mix.
        let p = quick();
        let rows = run_routing(&p);
        let random = rows.iter().find(|r| r.policy == "random").unwrap();
        let best_mean = rows
            .iter()
            .filter(|r| r.policy != "random")
            .map(|r| r.mean_ms)
            .fold(f64::INFINITY, f64::min);
        let best_p99 = rows
            .iter()
            .filter(|r| r.policy != "random")
            .map(|r| r.p99_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(best_mean < random.mean_ms, "{best_mean} vs {}", random.mean_ms);
        assert!(best_p99 < random.p99_ms, "{best_p99} vs {}", random.p99_ms);
    }

    #[test]
    fn renders() {
        let rows = run_routing(&quick());
        let text = render(&rows);
        assert!(text.contains("prefix-cache-aware"));
        assert!(text.contains("session-sticky"));
        assert!(text.contains("slo-aware"));
        assert!(text.contains("vs random"));
    }
}
