//! Composable scoring pipeline — the routing core (§3.2.2).
//!
//! Instead of a closed per-policy `match`, every pod is scored by a set of
//! LLM-aware scorers, each mapping a [`PodSnapshot`] signal into `[0, 1]`
//! (higher = better), and the pipeline picks the pod with the highest
//! **weighted sum**. The paper's six policies are presets over this core
//! (single weight 1.0 — see [`super::Policy`]); hybrids like
//! `0.6*prefix + 0.4*least-request` are just other weight vectors.
//!
//! Scorers:
//!   * `prefix_affinity` — 1.0 when the pod's local prefix cache covers at
//!     least `prefix_threshold` of the prompt AND the pod is not overloaded
//!     (see guard below), else 0.0. Binary by design: above the threshold
//!     the *load tie-break* spreads warm requests, which is exactly the
//!     legacy prefix-cache-aware behavior (affinity without hotspots).
//!   * `least_request` / `least_kv_cache` / `least_latency` / `throughput`
//!     — min-max normalized over the ready pods, inverted so the smallest
//!     signal scores 1.0.
//!   * `lora_residency` — 1.0 when the request's adapter is resident.
//!   * `fairness` — consumes [`ScoreCtx::tenant_share`] (recent token share
//!     of the requesting tenant, from [`super::fairness::TenantUsage`]):
//!     light tenants steer to idle pods, heavy tenants consolidate onto
//!     busy pods so they cannot spread queueing delay across the fleet.
//!
//! **Overload guard**: pods with more than `2 * cluster_min + 4` admitted
//! requests lose prefix affinity and latency credit, so stale signals and
//! cache affinity can never stampede one replica.
//!
//! **Determinism**: the decision is a pure function of (config, snapshots,
//! ctx). Ties break to the lower in-flight load, then to slice order.
//! (The legacy enum broke ties purely on slice order; preferring the
//! idler pod on exactly-equal signals — e.g. a fresh cluster where every
//! pod reports 0 tokens/s — is the one intentional behavior change.)
//!
//! **Perf**: `select` is allocation-free per request (scratch buffers live
//! in the pipeline; three O(pods) passes, no sorting) — it stays far under
//! the documented <5µs decision budget (`benches/microbench.rs` asserts
//! this in release mode).

use super::router::PodSnapshot;
use crate::workload::Request;

/// Weights + knobs for the scoring pipeline. All weights must be finite
/// and >= 0, with at least one > 0; `prefix_threshold` lives in `[0, 1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineConfig {
    pub prefix_affinity: f64,
    pub least_request: f64,
    pub least_kv_cache: f64,
    pub least_latency: f64,
    pub throughput: f64,
    pub lora_residency: f64,
    pub fairness: f64,
    /// Prompt-coverage fraction at which prefix affinity engages.
    pub prefix_threshold: f64,
    /// Eject overloaded pods from prefix/latency credit (legacy behavior).
    pub overload_guard: bool,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            prefix_affinity: 0.0,
            least_request: 0.0,
            least_kv_cache: 0.0,
            least_latency: 0.0,
            throughput: 0.0,
            lora_residency: 0.0,
            fairness: 0.0,
            prefix_threshold: 0.3,
            overload_guard: true,
        }
    }
}

impl PipelineConfig {
    /// Single-scorer preset helper. Panics on an unknown scorer name —
    /// callers pass compile-time literals, and silently returning an
    /// all-zero config would degrade routing to pure tie-breaking.
    pub fn single(scorer: &str, weight: f64) -> PipelineConfig {
        let mut cfg = PipelineConfig::default();
        match scorer {
            "prefix" => cfg.prefix_affinity = weight,
            "least-request" => cfg.least_request = weight,
            "least-kv-cache" => cfg.least_kv_cache = weight,
            "least-latency" => cfg.least_latency = weight,
            "throughput" => cfg.throughput = weight,
            "lora" => cfg.lora_residency = weight,
            "fairness" => cfg.fairness = weight,
            other => panic!("unknown scorer {other:?} (see PipelineConfig fields)"),
        }
        cfg
    }

    fn weights(&self) -> [f64; 7] {
        [
            self.prefix_affinity,
            self.least_request,
            self.least_kv_cache,
            self.least_latency,
            self.throughput,
            self.lora_residency,
            self.fairness,
        ]
    }

    /// Reject non-finite/negative weights, all-zero weight vectors, and
    /// out-of-range thresholds.
    pub fn validate(&self) -> Result<(), String> {
        for (w, name) in self.weights().iter().zip([
            "prefix", "least-request", "least-kv-cache", "least-latency", "throughput", "lora",
            "fairness",
        ]) {
            if !w.is_finite() || *w < 0.0 {
                return Err(format!("weight {name} must be finite and >= 0, got {w}"));
            }
        }
        if self.weights().iter().all(|&w| w == 0.0) {
            return Err("at least one scorer weight must be > 0".to_string());
        }
        if !self.prefix_threshold.is_finite()
            || !(0.0..=1.0).contains(&self.prefix_threshold)
        {
            return Err(format!(
                "prefix threshold must be in [0, 1], got {}",
                self.prefix_threshold
            ));
        }
        Ok(())
    }
}

/// Per-request context the gateway computes outside the router (signals
/// that are not per-pod).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScoreCtx {
    /// Requesting tenant's share of recent token usage, in `[0, 1]`
    /// (0 = unknown/light). Feeds the fairness scorer.
    pub tenant_share: f64,
}

/// Min/max aggregates over the ready pods (one prepass per decision).
#[derive(Debug, Clone, Copy)]
struct ReadyStats {
    min_load: usize,
    max_load: usize,
    min_kv: f64,
    max_kv: f64,
    min_lat: f64,
    max_lat: f64,
    min_tps: f64,
    max_tps: f64,
    any_ready: bool,
}

impl ReadyStats {
    fn of(pods: &[PodSnapshot]) -> ReadyStats {
        let mut s = ReadyStats {
            min_load: usize::MAX,
            max_load: 0,
            min_kv: f64::INFINITY,
            max_kv: f64::NEG_INFINITY,
            min_lat: f64::INFINITY,
            max_lat: f64::NEG_INFINITY,
            min_tps: f64::INFINITY,
            max_tps: f64::NEG_INFINITY,
            any_ready: false,
        };
        for p in pods.iter().filter(|p| p.ready) {
            s.any_ready = true;
            let load = p.stats.waiting + p.stats.running;
            s.min_load = s.min_load.min(load);
            s.max_load = s.max_load.max(load);
            s.min_kv = s.min_kv.min(p.stats.kv_utilization);
            s.max_kv = s.max_kv.max(p.stats.kv_utilization);
            s.min_lat = s.min_lat.min(p.stats.avg_latency_us);
            s.max_lat = s.max_lat.max(p.stats.avg_latency_us);
            s.min_tps = s.min_tps.min(p.stats.tokens_per_s);
            s.max_tps = s.max_tps.max(p.stats.tokens_per_s);
        }
        s
    }

    /// Legacy outlier bound: > 2x cluster-min in-flight (+4 slack).
    fn overloaded(&self, load: usize) -> bool {
        load > self.min_load.saturating_mul(2).saturating_add(4)
    }
}

/// Lower-is-better signal -> [0, 1] with the minimum at 1.0. Constant
/// signals score 1.0 everywhere (pure tie, resolved downstream).
fn norm_desc(v: f64, min: f64, max: f64) -> f64 {
    if max > min {
        (max - v) / (max - min)
    } else {
        1.0
    }
}

/// Higher-is-worse load position in [0, 1] (0 at the cluster minimum).
fn norm_asc(v: f64, min: f64, max: f64) -> f64 {
    if max > min {
        (v - min) / (max - min)
    } else {
        0.0
    }
}

/// The weighted scoring core. Holds only config + scratch, so it is cheap
/// to embed in [`super::Router`].
pub struct ScoringPipeline {
    cfg: PipelineConfig,
    /// Scratch: per-pod weighted totals, reused across requests.
    totals: Vec<f64>,
}

impl ScoringPipeline {
    pub fn new(cfg: PipelineConfig) -> ScoringPipeline {
        ScoringPipeline { cfg, totals: Vec::new() }
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Weighted total for one pod (NEG_INFINITY when not ready).
    fn score_pod(
        cfg: &PipelineConfig,
        req: &Request,
        p: &PodSnapshot,
        rs: &ReadyStats,
        ctx: &ScoreCtx,
    ) -> f64 {
        if !p.ready {
            return f64::NEG_INFINITY;
        }
        let load = p.stats.waiting + p.stats.running;
        let ejected = cfg.overload_guard && rs.overloaded(load);
        let mut total = 0.0;
        if cfg.prefix_affinity > 0.0 {
            let warm = !ejected && p.prefix_hit_fraction() >= cfg.prefix_threshold;
            total += cfg.prefix_affinity * if warm { 1.0 } else { 0.0 };
        }
        if cfg.least_request > 0.0 {
            total += cfg.least_request
                * norm_desc(load as f64, rs.min_load as f64, rs.max_load as f64);
        }
        if cfg.least_kv_cache > 0.0 {
            total += cfg.least_kv_cache * norm_desc(p.stats.kv_utilization, rs.min_kv, rs.max_kv);
        }
        if cfg.least_latency > 0.0 {
            let s = if ejected {
                0.0
            } else {
                norm_desc(p.stats.avg_latency_us, rs.min_lat, rs.max_lat)
            };
            total += cfg.least_latency * s;
        }
        if cfg.throughput > 0.0 {
            total += cfg.throughput * norm_desc(p.stats.tokens_per_s, rs.min_tps, rs.max_tps);
        }
        if cfg.lora_residency > 0.0 {
            let resident = req
                .adapter
                .as_ref()
                .map(|a| p.resident_adapters.iter().any(|r| r == a))
                .unwrap_or(false);
            total += cfg.lora_residency * if resident { 1.0 } else { 0.0 };
        }
        if cfg.fairness > 0.0 {
            let share = ctx.tenant_share.clamp(0.0, 1.0);
            let nl = norm_asc(load as f64, rs.min_load as f64, rs.max_load as f64);
            total += cfg.fairness * (share * nl + (1.0 - share) * (1.0 - nl));
        }
        total
    }

    /// Fill `out[i]` with pod i's weighted total (`NEG_INFINITY` for
    /// not-ready pods). Public for tests and observability endpoints.
    pub fn score_into(
        &self,
        req: &Request,
        pods: &[PodSnapshot],
        ctx: &ScoreCtx,
        out: &mut Vec<f64>,
    ) {
        let rs = ReadyStats::of(pods);
        out.clear();
        out.extend(pods.iter().map(|p| Self::score_pod(&self.cfg, req, p, &rs, ctx)));
    }

    /// Pick the best pod: highest weighted total, ties to the lower
    /// in-flight load, then to slice order. None when no pod is ready.
    pub fn select(&mut self, req: &Request, pods: &[PodSnapshot], ctx: &ScoreCtx) -> Option<usize> {
        let rs = ReadyStats::of(pods);
        if !rs.any_ready {
            return None;
        }
        // Scratch reuse: after warmup this never allocates.
        self.totals.clear();
        self.totals.reserve(pods.len());
        let mut best: Option<(usize, f64, usize)> = None; // (slice idx, total, load)
        for (i, p) in pods.iter().enumerate() {
            let total = Self::score_pod(&self.cfg, req, p, &rs, ctx);
            self.totals.push(total);
            if !p.ready {
                continue;
            }
            let load = p.stats.waiting + p.stats.running;
            let better = match best {
                None => true,
                Some((_, bt, bl)) => total > bt || (total == bt && load < bl),
            };
            if better {
                best = Some((i, total, load));
            }
        }
        best.map(|(i, _, _)| pods[i].pod)
    }

    /// Totals from the most recent `select` (observability/debug).
    pub fn last_totals(&self) -> &[f64] {
        &self.totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineStats;

    fn snap(pod: usize) -> PodSnapshot {
        PodSnapshot {
            pod,
            ready: true,
            stats: EngineStats::default(),
            prefix_match_blocks: 0,
            prompt_blocks: 10,
            resident_adapters: vec![],
        }
    }

    fn req() -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![0; 160],
            output_len: 1,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
        }
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(PipelineConfig::default().validate().is_err(), "all-zero weights");
        let mut c = PipelineConfig::single("least-request", 1.0);
        assert!(c.validate().is_ok());
        c.prefix_threshold = 1.5;
        assert!(c.validate().is_err());
        c.prefix_threshold = 0.5;
        c.fairness = -1.0;
        assert!(c.validate().is_err());
        c.fairness = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn hybrid_prefix_plus_load_balances() {
        // A warm-but-busy pod loses to an idle cold pod once the load weight
        // dominates — the hybrid the closed enum could not express.
        let mut cfg = PipelineConfig::single("prefix", 0.3);
        cfg.least_request = 0.7;
        cfg.overload_guard = false;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].prefix_match_blocks = 10; // warm
        pods[1].stats.waiting = 8; // but busy
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
        // Flip the weights: affinity wins.
        let mut cfg2 = PipelineConfig::single("prefix", 0.7);
        cfg2.least_request = 0.3;
        cfg2.overload_guard = false;
        let mut pl2 = ScoringPipeline::new(cfg2);
        assert_eq!(pl2.select(&req(), &pods, &ScoreCtx::default()), Some(1));
    }

    #[test]
    fn fairness_term_splits_light_and_heavy_tenants() {
        let cfg = PipelineConfig::single("fairness", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.waiting = 9;
        // Light tenant (share 0) -> idle pod.
        assert_eq!(
            pl.select(&req(), &pods, &ScoreCtx { tenant_share: 0.0 }),
            Some(1)
        );
        // Heavy tenant (share 1) consolidates onto the busy pod.
        assert_eq!(
            pl.select(&req(), &pods, &ScoreCtx { tenant_share: 1.0 }),
            Some(0)
        );
    }

    #[test]
    fn lora_residency_scorer() {
        let cfg = PipelineConfig::single("lora", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["a1".into()];
        let mut rq = req();
        rq.adapter = Some("a1".into());
        assert_eq!(pl.select(&rq, &pods, &ScoreCtx::default()), Some(1));
        // Without an adapter the term is inert -> load/order tie-break.
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
    }

    #[test]
    fn not_ready_pods_never_win() {
        let cfg = PipelineConfig::single("least-request", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].ready = false;
        pods[1].stats.waiting = 50;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        pods[1].ready = false;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), None);
    }

    #[test]
    fn score_into_matches_select() {
        let mut cfg = PipelineConfig::single("least-request", 0.5);
        cfg.least_kv_cache = 0.5;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[0].stats.waiting = 3;
        pods[1].stats.kv_utilization = 0.9;
        let mut scores = Vec::new();
        pl.score_into(&req(), &pods, &ScoreCtx::default(), &mut scores);
        let best = (0..pods.len())
            .max_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(pods[best].pod));
    }
}
