//! Composable scoring pipeline — the routing core (§3.2.2).
//!
//! Instead of a closed per-policy `match`, every pod is scored by a set of
//! LLM-aware scorers, each mapping a [`PodSnapshot`] signal into `[0, 1]`
//! (higher = better), and the pipeline picks the pod with the highest
//! **weighted sum**. The paper's six policies are presets over this core
//! (single weight 1.0 — see [`super::Policy`]); hybrids like
//! `0.6*prefix + 0.4*least-request` are just other weight vectors.
//!
//! Scorers:
//!   * `prefix_affinity` — 1.0 when the pod's local prefix cache covers at
//!     least `prefix_threshold` of the prompt AND the pod is not overloaded
//!     (see guard below), else 0.0. Binary by design: above the threshold
//!     the *load tie-break* spreads warm requests, which is exactly the
//!     legacy prefix-cache-aware behavior (affinity without hotspots).
//!   * `least_request` / `least_kv_cache` / `least_latency` / `throughput`
//!     — min-max normalized over the ready pods, inverted so the smallest
//!     signal scores 1.0.
//!   * `lora_residency` — 1.0 when the request's adapter is resident.
//!   * `fairness` — consumes [`ScoreCtx::tenant_share`] (recent token share
//!     of the requesting tenant, from [`super::fairness::TenantUsage`]):
//!     light tenants steer to idle pods, heavy tenants consolidate onto
//!     busy pods so they cannot spread queueing delay across the fleet.
//!   * `pool_affinity` — [`PodSnapshot::pool_hit_fraction`]: the fraction
//!     of the prompt resident in the distributed KV pool across its three
//!     residency classes — colocated RAM at full credit, remote RAM
//!     discounted (skips compute but pays the network), cold-tier blocks
//!     discounted further (promotable, but at disk cost). Continuous —
//!     ranks shard owners above remote readers above cold-tier holders
//!     above empty pods. Fed by `ClusterView` from the pool's residency
//!     probe, so the distributed pool becomes a *placement* signal.
//!   * `slo_headroom` — [`PodSnapshot::slo_headroom`]: room between the
//!     pod's recent latency and the request's SLO budget (TTFT + ITL x
//!     output cap, targets from `optimizer/profiles.rs`), 1 = far under
//!     target. Lets a mix trade prefix/pool affinity against deadline risk.
//!   * `session_affinity` — 1.0 when the request's session last routed to
//!     this pod (sticky multi-turn KV locality). Binary like prefix
//!     affinity; composes with the overload guard below, so a drowning
//!     pod sheds its sessions instead of hoarding them.
//!   * `health` — [`super::view::HealthState`] credit: 1.0 Healthy, 0.5
//!     Degraded. Draining/Cordoned pods never reach a score at all —
//!     every selection path hard-excludes pods that stopped accepting new
//!     work ([`PodSnapshot::accepts_new_work`]), whatever the weights; the
//!     scorer's job is steering work *away from suspects* before the
//!     machine escalates.
//!
//! **Overload guard**: pods with more than `2 * cluster_min + 4` admitted
//! requests lose prefix affinity and latency credit, so stale signals and
//! cache affinity can never stampede one replica.
//!
//! **Determinism**: the decision is a pure function of (config, snapshots,
//! ctx). Ties break to the lower in-flight load, then to slice order.
//! (The legacy enum broke ties purely on slice order; preferring the
//! idler pod on exactly-equal signals — e.g. a fresh cluster where every
//! pod reports 0 tokens/s — is the one intentional behavior change.)
//!
//! **Perf**: `select` is allocation-free per request (scratch buffers live
//! in the pipeline; three O(pods) passes, no sorting) — it stays far under
//! the documented <5µs decision budget (`benches/microbench.rs` asserts
//! this in release mode).

use super::router::PodSnapshot;
use super::view::HealthState;
use crate::workload::Request;

/// Number of scorers in the pipeline (and slots in a score-term vector).
pub const N_SCORERS: usize = 11;

/// Canonical scorer names, in [`PipelineConfig::weights`] order — the
/// labels used by `weighted:` strings, validation errors and the
/// `aibrix_route_scorer_contrib` metric.
pub const SCORER_NAMES: [&str; N_SCORERS] = [
    "prefix",
    "least-request",
    "least-kv-cache",
    "least-latency",
    "throughput",
    "lora",
    "fairness",
    "pool-affinity",
    "slo-headroom",
    "session-affinity",
    "health",
];

/// Weights + knobs for the scoring pipeline. All weights must be finite
/// and >= 0, with at least one > 0; `prefix_threshold` lives in `[0, 1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineConfig {
    pub prefix_affinity: f64,
    pub least_request: f64,
    pub least_kv_cache: f64,
    pub least_latency: f64,
    pub throughput: f64,
    pub lora_residency: f64,
    pub fairness: f64,
    /// Distributed-pool residency affinity (ClusterView signal).
    pub pool_affinity: f64,
    /// SLO latency-budget headroom (ClusterView signal).
    pub slo_headroom: f64,
    /// Session stickiness (ClusterView signal).
    pub session_affinity: f64,
    /// Health-machine credit (full for Healthy, half for Degraded).
    pub health: f64,
    /// Prompt-coverage fraction at which prefix affinity engages.
    pub prefix_threshold: f64,
    /// Eject overloaded pods from prefix/latency credit (legacy behavior).
    pub overload_guard: bool,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            prefix_affinity: 0.0,
            least_request: 0.0,
            least_kv_cache: 0.0,
            least_latency: 0.0,
            throughput: 0.0,
            lora_residency: 0.0,
            fairness: 0.0,
            pool_affinity: 0.0,
            slo_headroom: 0.0,
            session_affinity: 0.0,
            health: 0.0,
            prefix_threshold: 0.3,
            overload_guard: true,
        }
    }
}

impl PipelineConfig {
    /// Single-scorer preset helper. Callers pass compile-time literals;
    /// a typo'd scorer name leaves the weight vector all-zero, which
    /// `validate()` rejects and the debug assertion catches in every test
    /// run — release serving must not carry a panic path here (the
    /// gateway keeps routing on pure tie-breaking rather than dying).
    pub fn single(scorer: &str, weight: f64) -> PipelineConfig {
        let mut cfg = PipelineConfig::default();
        match scorer {
            "prefix" => cfg.prefix_affinity = weight,
            "least-request" => cfg.least_request = weight,
            "least-kv-cache" => cfg.least_kv_cache = weight,
            "least-latency" => cfg.least_latency = weight,
            "throughput" => cfg.throughput = weight,
            "lora" => cfg.lora_residency = weight,
            "fairness" => cfg.fairness = weight,
            "pool-affinity" => cfg.pool_affinity = weight,
            "slo-headroom" => cfg.slo_headroom = weight,
            "session-affinity" => cfg.session_affinity = weight,
            "health" => cfg.health = weight,
            other => {
                debug_assert!(false, "unknown scorer {other:?} (see PipelineConfig fields)");
            }
        }
        cfg
    }

    /// Weight vector in [`SCORER_NAMES`] order.
    pub fn weights(&self) -> [f64; N_SCORERS] {
        [
            self.prefix_affinity,
            self.least_request,
            self.least_kv_cache,
            self.least_latency,
            self.throughput,
            self.lora_residency,
            self.fairness,
            self.pool_affinity,
            self.slo_headroom,
            self.session_affinity,
            self.health,
        ]
    }

    /// Reject non-finite/negative weights, all-zero weight vectors, and
    /// out-of-range thresholds.
    pub fn validate(&self) -> Result<(), String> {
        for (w, name) in self.weights().iter().zip(SCORER_NAMES) {
            if !w.is_finite() || *w < 0.0 {
                return Err(format!("weight {name} must be finite and >= 0, got {w}"));
            }
        }
        if self.weights().iter().all(|&w| w == 0.0) {
            return Err("at least one scorer weight must be > 0".to_string());
        }
        if !self.prefix_threshold.is_finite()
            || !(0.0..=1.0).contains(&self.prefix_threshold)
        {
            return Err(format!(
                "prefix threshold must be in [0, 1], got {}",
                self.prefix_threshold
            ));
        }
        Ok(())
    }
}

/// Per-request context the gateway computes outside the router (signals
/// that are not per-pod).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScoreCtx {
    /// Requesting tenant's share of recent token usage, in `[0, 1]`
    /// (0 = unknown/light). Feeds the fairness scorer.
    pub tenant_share: f64,
}

/// Min/max aggregates over the ready pods (one prepass per decision).
#[derive(Debug, Clone, Copy)]
struct ReadyStats {
    min_load: usize,
    max_load: usize,
    min_kv: f64,
    max_kv: f64,
    min_lat: f64,
    max_lat: f64,
    min_tps: f64,
    max_tps: f64,
    any_ready: bool,
}

impl ReadyStats {
    fn of(pods: &[PodSnapshot]) -> ReadyStats {
        let mut s = ReadyStats {
            min_load: usize::MAX,
            max_load: 0,
            min_kv: f64::INFINITY,
            max_kv: f64::NEG_INFINITY,
            min_lat: f64::INFINITY,
            max_lat: f64::NEG_INFINITY,
            min_tps: f64::INFINITY,
            max_tps: f64::NEG_INFINITY,
            any_ready: false,
        };
        // Aggregates span the pods still accepting new work: a draining
        // pod's (often pathological) stats must not skew normalization for
        // the pods that can actually win.
        for p in pods.iter().filter(|p| p.accepts_new_work()) {
            s.any_ready = true;
            let load = p.stats.waiting + p.stats.running;
            s.min_load = s.min_load.min(load);
            s.max_load = s.max_load.max(load);
            s.min_kv = s.min_kv.min(p.stats.kv_utilization);
            s.max_kv = s.max_kv.max(p.stats.kv_utilization);
            s.min_lat = s.min_lat.min(p.stats.avg_latency_us);
            s.max_lat = s.max_lat.max(p.stats.avg_latency_us);
            s.min_tps = s.min_tps.min(p.stats.tokens_per_s);
            s.max_tps = s.max_tps.max(p.stats.tokens_per_s);
        }
        s
    }

    /// Legacy outlier bound: > 2x cluster-min in-flight (+4 slack).
    fn overloaded(&self, load: usize) -> bool {
        load > self.min_load.saturating_mul(2).saturating_add(4)
    }
}

/// Lower-is-better signal -> [0, 1] with the minimum at 1.0. Constant
/// signals score 1.0 everywhere (pure tie, resolved downstream).
fn norm_desc(v: f64, min: f64, max: f64) -> f64 {
    if max > min {
        (max - v) / (max - min)
    } else {
        1.0
    }
}

/// Higher-is-worse load position in [0, 1] (0 at the cluster minimum).
fn norm_asc(v: f64, min: f64, max: f64) -> f64 {
    if max > min {
        (v - min) / (max - min)
    } else {
        0.0
    }
}

/// Cumulative routing observability: how much each scorer contributed to
/// the winning pods, plus affinity hit counters. Sums of weighted terms —
/// divide by `decisions` for the mean contribution per decision (what
/// `/metrics` exports as `aibrix_route_scorer_contrib{scorer}`).
#[derive(Debug, Clone, Default)]
pub struct RouteTelemetry {
    /// Scoring decisions made (Random-policy routers never count here).
    pub decisions: u64,
    /// Per-scorer weighted contribution to winners, [`SCORER_NAMES`] order.
    pub contrib: [f64; N_SCORERS],
    /// Decisions whose winner had a positive pool-affinity term.
    pub pool_affinity_hits: u64,
    /// Decisions whose winner held the request's session.
    pub session_hits: u64,
}

/// The weighted scoring core. Holds only config + scratch, so it is cheap
/// to embed in [`super::Router`].
pub struct ScoringPipeline {
    cfg: PipelineConfig,
    /// Scratch: per-pod weighted totals, reused across requests.
    totals: Vec<f64>,
    telemetry: RouteTelemetry,
}

impl ScoringPipeline {
    pub fn new(cfg: PipelineConfig) -> ScoringPipeline {
        ScoringPipeline { cfg, totals: Vec::new(), telemetry: RouteTelemetry::default() }
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Cumulative per-scorer contribution counters (observability).
    pub fn telemetry(&self) -> &RouteTelemetry {
        &self.telemetry
    }

    /// Weighted per-scorer terms for one pod, [`SCORER_NAMES`] order.
    /// Callers must gate on `p.ready` themselves (a not-ready pod has no
    /// meaningful terms).
    fn score_terms(
        cfg: &PipelineConfig,
        req: &Request,
        p: &PodSnapshot,
        rs: &ReadyStats,
        ctx: &ScoreCtx,
    ) -> [f64; N_SCORERS] {
        let mut t = [0.0; N_SCORERS];
        let load = p.stats.waiting + p.stats.running;
        let ejected = cfg.overload_guard && rs.overloaded(load);
        if cfg.prefix_affinity > 0.0 {
            let warm = !ejected && p.prefix_hit_fraction() >= cfg.prefix_threshold;
            t[0] = cfg.prefix_affinity * if warm { 1.0 } else { 0.0 };
        }
        if cfg.least_request > 0.0 {
            t[1] = cfg.least_request
                * norm_desc(load as f64, rs.min_load as f64, rs.max_load as f64);
        }
        if cfg.least_kv_cache > 0.0 {
            t[2] = cfg.least_kv_cache * norm_desc(p.stats.kv_utilization, rs.min_kv, rs.max_kv);
        }
        if cfg.least_latency > 0.0 {
            let s = if ejected {
                0.0
            } else {
                norm_desc(p.stats.avg_latency_us, rs.min_lat, rs.max_lat)
            };
            t[3] = cfg.least_latency * s;
        }
        if cfg.throughput > 0.0 {
            t[4] = cfg.throughput * norm_desc(p.stats.tokens_per_s, rs.min_tps, rs.max_tps);
        }
        if cfg.lora_residency > 0.0 {
            let resident = req
                .adapter
                .as_ref()
                .map(|a| p.resident_adapters.iter().any(|r| r == a))
                .unwrap_or(false);
            t[5] = cfg.lora_residency * if resident { 1.0 } else { 0.0 };
        }
        if cfg.fairness > 0.0 {
            let share = ctx.tenant_share.clamp(0.0, 1.0);
            let nl = norm_asc(load as f64, rs.min_load as f64, rs.max_load as f64);
            t[6] = cfg.fairness * (share * nl + (1.0 - share) * (1.0 - nl));
        }
        // The ClusterView scorers all respect the overload guard: affinity
        // of any kind must never pile work onto a drowning pod.
        if cfg.pool_affinity > 0.0 && !ejected {
            t[7] = cfg.pool_affinity * p.pool_hit_fraction();
        }
        if cfg.slo_headroom > 0.0 && !ejected {
            t[8] = cfg.slo_headroom * p.slo_headroom.clamp(0.0, 1.0);
        }
        if cfg.session_affinity > 0.0 && !ejected && p.session_match {
            t[9] = cfg.session_affinity;
        }
        if cfg.health > 0.0 {
            let credit = match p.health {
                HealthState::Healthy => 1.0,
                HealthState::Degraded => 0.5,
                // Unreachable through select (hard-excluded), but
                // score_into reports honest zeros for observability.
                HealthState::Draining | HealthState::Cordoned => 0.0,
            };
            t[10] = cfg.health * credit;
        }
        t
    }

    /// Weighted total for one pod (NEG_INFINITY when not ready or no
    /// longer accepting new work — Draining/Cordoned).
    fn score_pod(
        cfg: &PipelineConfig,
        req: &Request,
        p: &PodSnapshot,
        rs: &ReadyStats,
        ctx: &ScoreCtx,
    ) -> f64 {
        if !p.accepts_new_work() {
            return f64::NEG_INFINITY;
        }
        Self::score_terms(cfg, req, p, rs, ctx).iter().sum()
    }

    /// Fill `out[i]` with pod i's weighted total (`NEG_INFINITY` for
    /// not-ready pods). Public for tests and observability endpoints.
    pub fn score_into(
        &self,
        req: &Request,
        pods: &[PodSnapshot],
        ctx: &ScoreCtx,
        out: &mut Vec<f64>,
    ) {
        let rs = ReadyStats::of(pods);
        out.clear();
        out.extend(pods.iter().map(|p| Self::score_pod(&self.cfg, req, p, &rs, ctx)));
    }

    /// Pick the best pod: highest weighted total, ties to the lower
    /// in-flight load, then to slice order. None when no pod is ready.
    pub fn select(&mut self, req: &Request, pods: &[PodSnapshot], ctx: &ScoreCtx) -> Option<usize> {
        let rs = ReadyStats::of(pods);
        if !rs.any_ready {
            return None;
        }
        // Scratch reuse: after warmup this never allocates.
        self.totals.clear();
        self.totals.reserve(pods.len());
        let mut best: Option<(usize, f64, usize)> = None; // (slice idx, total, load)
        for (i, p) in pods.iter().enumerate() {
            let total = Self::score_pod(&self.cfg, req, p, &rs, ctx);
            self.totals.push(total);
            if !p.accepts_new_work() {
                continue;
            }
            let load = p.stats.waiting + p.stats.running;
            let better = match best {
                None => true,
                Some((_, bt, bl)) => total > bt || (total == bt && load < bl),
            };
            if better {
                best = Some((i, total, load));
            }
        }
        // Observability: attribute the winner's score to its scorers (one
        // extra O(scorers) pass over a single pod — negligible vs the
        // decision itself, and it keeps the hot loop accumulation-free).
        if let Some((i, _, _)) = best {
            let terms = Self::score_terms(&self.cfg, req, &pods[i], &rs, ctx);
            self.telemetry.decisions += 1;
            for (acc, t) in self.telemetry.contrib.iter_mut().zip(terms) {
                *acc += t;
            }
            if terms[7] > 0.0 {
                self.telemetry.pool_affinity_hits += 1;
            }
            if pods[i].session_match {
                self.telemetry.session_hits += 1;
            }
        }
        best.map(|(i, _, _)| pods[i].pod)
    }

    /// Totals from the most recent `select` (observability/debug).
    pub fn last_totals(&self) -> &[f64] {
        &self.totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pod: usize) -> PodSnapshot {
        PodSnapshot { pod, prompt_blocks: 10, ..Default::default() }
    }

    fn req() -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![0; 160],
            output_len: 1,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: Default::default(),
        }
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(PipelineConfig::default().validate().is_err(), "all-zero weights");
        let mut c = PipelineConfig::single("least-request", 1.0);
        assert!(c.validate().is_ok());
        c.prefix_threshold = 1.5;
        assert!(c.validate().is_err());
        c.prefix_threshold = 0.5;
        c.fairness = -1.0;
        assert!(c.validate().is_err());
        c.fairness = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn hybrid_prefix_plus_load_balances() {
        // A warm-but-busy pod loses to an idle cold pod once the load weight
        // dominates — the hybrid the closed enum could not express.
        let mut cfg = PipelineConfig::single("prefix", 0.3);
        cfg.least_request = 0.7;
        cfg.overload_guard = false;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].prefix_match_blocks = 10; // warm
        pods[1].stats.waiting = 8; // but busy
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
        // Flip the weights: affinity wins.
        let mut cfg2 = PipelineConfig::single("prefix", 0.7);
        cfg2.least_request = 0.3;
        cfg2.overload_guard = false;
        let mut pl2 = ScoringPipeline::new(cfg2);
        assert_eq!(pl2.select(&req(), &pods, &ScoreCtx::default()), Some(1));
    }

    #[test]
    fn fairness_term_splits_light_and_heavy_tenants() {
        let cfg = PipelineConfig::single("fairness", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.waiting = 9;
        // Light tenant (share 0) -> idle pod.
        assert_eq!(
            pl.select(&req(), &pods, &ScoreCtx { tenant_share: 0.0 }),
            Some(1)
        );
        // Heavy tenant (share 1) consolidates onto the busy pod.
        assert_eq!(
            pl.select(&req(), &pods, &ScoreCtx { tenant_share: 1.0 }),
            Some(0)
        );
    }

    #[test]
    fn lora_residency_scorer() {
        let cfg = PipelineConfig::single("lora", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["a1".into()];
        let mut rq = req();
        rq.adapter = Some("a1".into());
        assert_eq!(pl.select(&rq, &pods, &ScoreCtx::default()), Some(1));
        // Without an adapter the term is inert -> load/order tie-break.
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
    }

    #[test]
    fn not_ready_pods_never_win() {
        let cfg = PipelineConfig::single("least-request", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].ready = false;
        pods[1].stats.waiting = 50;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        pods[1].ready = false;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), None);
    }

    #[test]
    fn pool_affinity_ranks_local_over_remote_over_cold() {
        let cfg = PipelineConfig::single("pool-affinity", 1.0);
        let pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1), snap(2), snap(3)];
        // Pod 0: 6 blocks on its own shard; pod 1: same 6 visible but all
        // remote RAM; pod 2: same 6 but spilled to the cold tier; pod 3:
        // nothing. Strict ordering across all four residency situations.
        pods[0].pool_blocks_local = 6;
        pods[0].pool_blocks_total = 6;
        pods[1].pool_blocks_total = 6;
        pods[2].pool_blocks_total = 6;
        pods[2].pool_blocks_cold = 6;
        let mut scores = Vec::new();
        pl.score_into(&req(), &pods, &ScoreCtx::default(), &mut scores);
        assert!(scores[0] > scores[1], "{scores:?}");
        assert!(scores[1] > scores[2], "{scores:?}");
        assert!(scores[2] > scores[3], "{scores:?}");
    }

    #[test]
    fn slo_headroom_scorer_prefers_slack() {
        let cfg = PipelineConfig::single("slo-headroom", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].slo_headroom = 0.2;
        pods[1].slo_headroom = 0.8;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        // Out-of-range view values are clamped, not amplified.
        pods[0].slo_headroom = 7.0;
        pods[1].slo_headroom = 1.0;
        let mut scores = Vec::new();
        pl.score_into(&req(), &pods, &ScoreCtx::default(), &mut scores);
        assert_eq!(scores[0], scores[1]);
    }

    #[test]
    fn session_affinity_respects_overload_guard() {
        let cfg = PipelineConfig::single("session-affinity", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].session_match = true;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        // Sticky pod far above cluster-min load loses its claim.
        pods[1].stats.waiting = 25;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
    }

    #[test]
    fn telemetry_attributes_winner_contributions() {
        let mut cfg = PipelineConfig::single("pool-affinity", 0.6);
        cfg.least_request = 0.4;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].pool_blocks_local = 10;
        pods[1].pool_blocks_total = 10;
        pods[1].session_match = true;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        let t = pl.telemetry();
        assert_eq!(t.decisions, 2);
        assert_eq!(t.pool_affinity_hits, 2);
        assert_eq!(t.session_hits, 2);
        // pool term = 0.6 * 1.0 per decision; names align with the array.
        let pool_idx = SCORER_NAMES.iter().position(|&n| n == "pool-affinity").unwrap();
        assert!((t.contrib[pool_idx] - 1.2).abs() < 1e-12, "{:?}", t.contrib);
        // Unweighted scorers contribute nothing.
        let lora_idx = SCORER_NAMES.iter().position(|&n| n == "lora").unwrap();
        assert_eq!(t.contrib[lora_idx], 0.0);
    }

    #[test]
    fn health_scorer_steers_away_from_degraded() {
        let mut cfg = PipelineConfig::single("health", 0.8);
        cfg.least_request = 0.2;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].health = HealthState::Degraded;
        pods[0].stats.waiting = 1;
        pods[1].stats.waiting = 2; // slightly busier but healthy
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        // With the suspect recovered the load term decides again.
        pods[0].health = HealthState::Healthy;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(0));
    }

    #[test]
    fn draining_excluded_whatever_the_weights() {
        // Zero health weight: exclusion is structural, not score-driven.
        let cfg = PipelineConfig::single("least-request", 1.0);
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].health = HealthState::Draining; // idle but draining
        pods[1].stats.waiting = 40;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(1));
        let mut scores = Vec::new();
        pl.score_into(&req(), &pods, &ScoreCtx::default(), &mut scores);
        assert_eq!(scores[0], f64::NEG_INFINITY);
        pods[1].health = HealthState::Cordoned;
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), None);
    }

    #[test]
    fn score_into_matches_select() {
        let mut cfg = PipelineConfig::single("least-request", 0.5);
        cfg.least_kv_cache = 0.5;
        let mut pl = ScoringPipeline::new(cfg);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[0].stats.waiting = 3;
        pods[1].stats.kv_utilization = 0.9;
        let mut scores = Vec::new();
        pl.score_into(&req(), &pods, &ScoreCtx::default(), &mut scores);
        let best = (0..pods.len())
            .max_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        assert_eq!(pl.select(&req(), &pods, &ScoreCtx::default()), Some(pods[best].pod));
    }
}
