//! Advanced LLM gateway (§3.2.2, Figure 3).
//!
//! The paper extends Envoy Gateway with LLM-aware routing; here the gateway
//! is native Rust (DESIGN.md §2), built around a **composable scoring
//! pipeline** rather than a closed policy enum:
//!
//!   * [`scoring`] — the routing core. Each pod snapshot is scored by a set
//!     of scorers (prefix-affinity, least-request, least-kv-cache,
//!     least-latency, throughput, LoRA-residency, fairness), each emitting
//!     `[0, 1]`; a weighted sum with deterministic tie-breaking (lower
//!     in-flight load, then slice order) picks the pod. An overload guard
//!     strips prefix/latency credit from pods above `2x cluster-min + 4`
//!     in-flight so affinity can never create hotspots.
//!   * [`router`] — [`Policy`]: the six paper policies (`random`,
//!     `throughput`, `least-request`, `least-kv-cache`, `least-latency`,
//!     `prefix-cache-aware`) are canned presets over the pipeline: one
//!     scorer at weight 1.0 reproduces the legacy closed-enum routing
//!     whenever the primary signal distinguishes the pods
//!     (property-tested against the ported legacy match in
//!     `tests/gateway_pipeline.rs`); on *exactly equal* signals the
//!     pipeline breaks the tie toward the lower in-flight load where the
//!     legacy match took pure slice order — a deliberate improvement
//!     (ties go to the idler pod), not an oversight. Meanwhile
//!     [`Policy::Weighted`] / `weighted:prefix=0.6,least-request=0.4`
//!     expresses hybrids the enum could not.
//!   * [`view`] — **ClusterView**, the unified signal plane: one snapshot
//!     producer composing per-replica load/latency/KV stats, distributed
//!     KV-pool residency (per-node, via [`crate::kvcache::DistKvPool::residency`]),
//!     SLO targets and bounded session tables into the [`PodSnapshot`]s
//!     every entry point routes from. Four scorers consume its signals:
//!     `pool-affinity`, `slo-headroom`, `session-affinity` (presets
//!     `pool-aware`, `slo-aware`, `session-sticky`) and `health`. The view
//!     also hosts the **health state machine** (`Healthy → Degraded →
//!     Draining → Cordoned`, fed by `diagnostics::diagnose` verdicts plus
//!     missed-heartbeat/straggler detection): Draining pods stop receiving
//!     new work, Cordoned pods are excluded outright, and sticky sessions
//!     pinned to either are invalidated on the spot.
//!   * [`ratelimit`] — the TPM/RPM token buckets.
//!   * [`admission`] — predictive overload admission: tier-aware pressure
//!     shedding (Batch first, Interactive last) plus deadline-feasibility
//!     rejection from ClusterView's queue-depth/throughput/KV signals,
//!     composing with (never replacing) the token buckets.
//!   * [`fairness`] — the per-tenant DRR dispatch queue plus
//!     [`TenantUsage`], the decayed token meter behind the fairness scorer.
//!
//! Preset -> pipeline mapping: `throughput`/`least-request`/
//! `least-kv-cache` are their single scorer at weight 1.0;
//! `least-latency` adds the overload guard (outlier ejection);
//! `prefix-cache-aware[=t]` is the prefix scorer (binary above threshold
//! `t`, default 0.3) whose load tie-break yields the legacy
//! "least-loaded warm pod, else least-request" behavior; `random` bypasses
//! scoring via the seeded RNG.
//!
//! **Perf budget**: one routing decision must stay under **5µs** (the
//! coordinator serves every request; engine steps are ms-scale). The
//! pipeline is allocation-free per request — scratch lives in the router —
//! and `benches/microbench.rs` asserts the budget in release mode.
//!
//! [`Gateway`] composes rate limiting -> fairness accounting -> routing
//! into the request entry point used by the sim harness and the HTTP
//! server.

pub mod admission;
pub mod fairness;
pub mod ratelimit;
pub mod router;
pub mod scoring;
pub mod view;

pub use admission::{tier_index, AdmissionConfig, AdmissionController, AdmissionCounters, Shed};
pub use fairness::{FairQueue, TenantUsage};
pub use ratelimit::{RateLimitConfig, RateLimiter};
pub use router::{
    PodSnapshot, Policy, Router, COLD_POOL_CREDIT, DEFAULT_PREFIX_THRESHOLD, REMOTE_POOL_CREDIT,
};
pub use scoring::{
    PipelineConfig, RouteTelemetry, ScoreCtx, ScoringPipeline, N_SCORERS, SCORER_NAMES,
};
pub use view::{
    fleet_kv_pressure, fleet_pressure, ClusterView, ClusterViewConfig, CounterPod, HealthPolicy,
    HealthState, HealthTracker, PodSignalSource, PodSignals,
};

use crate::chaos::RejectReason;
use crate::sim::SimTime;
use crate::workload::Request;

/// Gateway admission outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Route to pod (engine) index.
    Route(usize),
    /// 429: per-tenant rate limit exceeded.
    RateLimited { retry_after_ms: u64 },
    /// 429/503: predictive admission control refused the request —
    /// overload shedding ([`RejectReason::AdmissionShed`]) or an
    /// unmeetable deadline ([`RejectReason::DeadlineExceeded`]) — with a
    /// Retry-After hint (0 = retrying as-is is futile).
    Shed { reason: RejectReason, retry_after_ms: u64 },
    /// 503: no ready pod.
    NoCapacity,
}

/// The LLM gateway: rate limiting -> admission control -> fairness
/// accounting -> routing.
pub struct Gateway {
    pub router: Router,
    pub limiter: Option<RateLimiter>,
    /// Predictive overload admission (tier-aware shedding, deadline
    /// feasibility). `None` = admit everything the limiter allows.
    pub admission: Option<AdmissionController>,
    /// Decayed per-tenant token meter feeding the fairness scorer.
    pub usage: TenantUsage,
}

impl Gateway {
    pub fn new(policy: Policy, seed: u64) -> Gateway {
        Gateway {
            router: Router::new(policy, seed),
            limiter: None,
            admission: None,
            usage: TenantUsage::default(),
        }
    }

    pub fn with_rate_limits(mut self, cfg: RateLimitConfig) -> Gateway {
        self.limiter = Some(RateLimiter::new(cfg));
        self
    }

    pub fn with_admission(mut self, cfg: AdmissionConfig) -> Gateway {
        self.admission = Some(AdmissionController::new(cfg));
        self
    }

    /// Admit and route one request against the current pod snapshots:
    /// per-tenant token buckets first (quota), then predictive admission
    /// (cluster overload + deadline feasibility), then scoring/routing.
    /// Routing only reads the fairness meter; tokens are charged by
    /// [`Gateway::complete`] when the request finishes — *served* usage,
    /// not admission-time promises (`output_len` is a request cap, not
    /// what the engine will actually deliver).
    pub fn dispatch(&mut self, now: SimTime, req: &Request, pods: &[PodSnapshot]) -> Decision {
        if let Some(lim) = &mut self.limiter {
            if let Err(retry_after_ms) = lim.check(now, req.user, req.total_tokens() as u64) {
                return Decision::RateLimited { retry_after_ms };
            }
        }
        if let Some(adm) = &mut self.admission {
            if let Err(shed) = adm.evaluate(now, req, pods) {
                return Decision::Shed {
                    reason: shed.reason,
                    retry_after_ms: shed.retry_after_ms,
                };
            }
        }
        let ctx = ScoreCtx { tenant_share: self.usage.share(now, req.user) };
        match self.router.select_with_ctx(req, pods, &ctx) {
            Some(pod) => Decision::Route(pod),
            None => Decision::NoCapacity,
        }
    }

    /// Account a finished request: charge the tokens actually served
    /// (prompt + generated) to the tenant's fairness meter. Rejected or
    /// still-running requests never weigh on routing.
    pub fn complete(&mut self, now: SimTime, user: u32, served_tokens: u64) {
        self.usage.record(now, user, served_tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pod(id: usize) -> PodSnapshot {
        PodSnapshot { pod: id, prompt_blocks: 1, ..Default::default() }
    }

    fn req(user: u32, tokens: usize) -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![1; tokens],
            output_len: 10,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: crate::workload::Tier::Standard,
        }
    }

    #[test]
    fn routes_when_capacity() {
        let mut gw = Gateway::new(Policy::Random, 1);
        let d = gw.dispatch(0, &req(0, 100), &[pod(0), pod(1)]);
        assert!(matches!(d, Decision::Route(_)));
    }

    #[test]
    fn no_capacity_when_no_ready_pods() {
        let mut gw = Gateway::new(Policy::Random, 1);
        let mut p = pod(0);
        p.ready = false;
        assert_eq!(gw.dispatch(0, &req(0, 10), &[p]), Decision::NoCapacity);
        assert_eq!(gw.dispatch(0, &req(0, 10), &[]), Decision::NoCapacity);
    }

    #[test]
    fn rate_limit_rejects_then_recovers() {
        use crate::sim::SECONDS;
        let cfg = RateLimitConfig { rpm: 2, tpm: 1_000_000 };
        let mut gw = Gateway::new(Policy::Random, 1).with_rate_limits(cfg);
        let pods = [pod(0)];
        assert!(matches!(gw.dispatch(0, &req(7, 10), &pods), Decision::Route(_)));
        assert!(matches!(gw.dispatch(0, &req(7, 10), &pods), Decision::Route(_)));
        assert!(matches!(
            gw.dispatch(0, &req(7, 10), &pods),
            Decision::RateLimited { .. }
        ));
        // A different tenant is unaffected.
        assert!(matches!(gw.dispatch(0, &req(8, 10), &pods), Decision::Route(_)));
        // After a minute the bucket refills.
        assert!(matches!(
            gw.dispatch(61 * SECONDS, &req(7, 10), &pods),
            Decision::Route(_)
        ));
    }

    #[test]
    fn admission_composes_after_the_rate_limiter() {
        use crate::workload::Tier;
        let cfg = RateLimitConfig { rpm: 1, tpm: 1_000_000 };
        let mut gw = Gateway::new(Policy::Random, 1)
            .with_rate_limits(cfg)
            .with_admission(AdmissionConfig::default());
        let mut hot = pod(0);
        hot.stats.pressure = 0.99;
        // Within quota, the saturated fleet sheds; once the quota is
        // spent, the limiter answers first (admission never sees it).
        assert!(matches!(gw.dispatch(0, &req(7, 10), &[hot.clone()]), Decision::Shed { .. }));
        assert!(matches!(
            gw.dispatch(0, &req(7, 10), &[hot.clone()]),
            Decision::RateLimited { .. }
        ));
        // A within-quota tenant is shed by pressure with a typed reason.
        let mut r = req(8, 10);
        r.tier = Tier::Batch;
        match gw.dispatch(0, &r, &[hot]) {
            Decision::Shed { reason, retry_after_ms } => {
                assert_eq!(reason, RejectReason::AdmissionShed);
                assert!(retry_after_ms > 0);
            }
            other => panic!("expected a shed, got {other:?}"),
        }
        // Calm fleet: the same request routes.
        assert!(matches!(gw.dispatch(0, &req(9, 10), &[pod(0)]), Decision::Route(_)));
        let c = gw.admission.as_ref().unwrap().counters();
        assert_eq!(c.admitted[tier_index(Tier::Standard)], 1);
        assert!(c.total_shed() >= 2);
    }

    #[test]
    fn fairness_share_steers_heavy_tenant_to_busy_pod() {
        // A fairness-weighted gateway: tenant 1 has hogged tokens, tenant 2
        // is new. The heavy tenant consolidates onto the busy pod; the
        // light tenant gets the idle one.
        let policy = Policy::parse("weighted:fairness=1").unwrap();
        let mut gw = Gateway::new(policy, 1);
        let mut pods = vec![pod(0), pod(1)];
        pods[0].stats.waiting = 9;
        for _ in 0..50 {
            gw.usage.record(0, 1, 10_000);
        }
        gw.usage.record(0, 2, 10); // share(2) ~ 0
        assert_eq!(gw.dispatch(1000, &req(1, 10), &pods), Decision::Route(0));
        assert_eq!(gw.dispatch(1000, &req(2, 10), &pods), Decision::Route(1));
    }

    #[test]
    fn usage_charged_at_completion_not_admission() {
        let mut gw = Gateway::new(Policy::LeastRequest, 1);
        let mut down = pod(0);
        down.ready = false;
        assert_eq!(gw.dispatch(0, &req(3, 500), &[down]), Decision::NoCapacity);
        assert_eq!(gw.usage.share(0, 3), 0.0, "rejected request not charged");
        assert!(matches!(gw.dispatch(0, &req(3, 500), &[pod(0)]), Decision::Route(0)));
        assert_eq!(gw.usage.share(0, 3), 0.0, "admission alone charges nothing");
        // Completion charges what was actually served.
        gw.complete(10, 3, 520);
        assert!(gw.usage.share(10, 3) > 0.99, "sole tenant owns the meter");
    }
}
