//! Advanced LLM gateway (§3.2.2, Figure 3).
//!
//! The paper extends Envoy Gateway with LLM-aware routing; here the gateway
//! is native Rust (DESIGN.md §2): [`router`] implements the six routing
//! policies the paper lists, [`ratelimit`] the TPM/RPM token buckets, and
//! [`fairness`] the per-tenant dispatch queue. [`Gateway`] composes them
//! into the request entry point used by the sim harness and the HTTP
//! server.

pub mod fairness;
pub mod ratelimit;
pub mod router;

pub use fairness::FairQueue;
pub use ratelimit::{RateLimitConfig, RateLimiter};
pub use router::{PodSnapshot, Policy, Router};

use crate::sim::SimTime;
use crate::workload::Request;

/// Gateway admission outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Route to pod (engine) index.
    Route(usize),
    /// 429: per-tenant rate limit exceeded.
    RateLimited { retry_after_ms: u64 },
    /// 503: no ready pod.
    NoCapacity,
}

/// The LLM gateway: rate limiting -> routing.
pub struct Gateway {
    pub router: Router,
    pub limiter: Option<RateLimiter>,
}

impl Gateway {
    pub fn new(policy: Policy, seed: u64) -> Gateway {
        Gateway { router: Router::new(policy, seed), limiter: None }
    }

    pub fn with_rate_limits(mut self, cfg: RateLimitConfig) -> Gateway {
        self.limiter = Some(RateLimiter::new(cfg));
        self
    }

    /// Admit and route one request against the current pod snapshots.
    pub fn dispatch(
        &mut self,
        now: SimTime,
        req: &Request,
        pods: &[PodSnapshot],
    ) -> Decision {
        if let Some(lim) = &mut self.limiter {
            if let Err(retry_after_ms) = lim.check(now, req.user, req.total_tokens() as u64) {
                return Decision::RateLimited { retry_after_ms };
            }
        }
        match self.router.select(req, pods) {
            Some(pod) => Decision::Route(pod),
            None => Decision::NoCapacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineStats;

    fn pod(id: usize) -> PodSnapshot {
        PodSnapshot {
            pod: id,
            ready: true,
            stats: EngineStats::default(),
            prefix_match_blocks: 0,
            prompt_blocks: 1,
            resident_adapters: vec![],
        }
    }

    fn req(user: u32, tokens: usize) -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![1; tokens],
            output_len: 10,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user,
            shared_prefix_len: 0,
        }
    }

    #[test]
    fn routes_when_capacity() {
        let mut gw = Gateway::new(Policy::Random, 1);
        let d = gw.dispatch(0, &req(0, 100), &[pod(0), pod(1)]);
        assert!(matches!(d, Decision::Route(_)));
    }

    #[test]
    fn no_capacity_when_no_ready_pods() {
        let mut gw = Gateway::new(Policy::Random, 1);
        let mut p = pod(0);
        p.ready = false;
        assert_eq!(gw.dispatch(0, &req(0, 10), &[p]), Decision::NoCapacity);
        assert_eq!(gw.dispatch(0, &req(0, 10), &[]), Decision::NoCapacity);
    }

    #[test]
    fn rate_limit_rejects_then_recovers() {
        use crate::sim::SECONDS;
        let cfg = RateLimitConfig { rpm: 2, tpm: 1_000_000 };
        let mut gw = Gateway::new(Policy::Random, 1).with_rate_limits(cfg);
        let pods = [pod(0)];
        assert!(matches!(gw.dispatch(0, &req(7, 10), &pods), Decision::Route(_)));
        assert!(matches!(gw.dispatch(0, &req(7, 10), &pods), Decision::Route(_)));
        assert!(matches!(
            gw.dispatch(0, &req(7, 10), &pods),
            Decision::RateLimited { .. }
        ));
        // A different tenant is unaffected.
        assert!(matches!(gw.dispatch(0, &req(8, 10), &pods), Decision::Route(_)));
        // After a minute the bucket refills.
        assert!(matches!(
            gw.dispatch(61 * SECONDS, &req(7, 10), &pods),
            Decision::Route(_)
        ));
    }
}
