//! Predictive admission control — the overload-protection front door.
//!
//! Under sustained overload, admitting everything maximizes *throughput
//! of failure*: every queue grows, every deadline blows, and goodput
//! (SLO-met completions per second) collapses even though the engines
//! never idle. The admission controller sheds work **before** it
//! enqueues, using only ClusterView signals the gateway already has:
//!
//!   * **backpressure** — engines publish an overload pressure level in
//!     `[0, 1]` ([`crate::engine::EngineStats::pressure`], max of KV
//!     utilization and queue-depth); the fleet-worst value gates
//!     admission with **per-tier thresholds**, so Batch traffic sheds
//!     first, Standard next, and Interactive only at the brink;
//!   * **deadline feasibility** — a request carrying a TTFT deadline is
//!     rejected up front when the best-case queue-ahead service time
//!     (waiting depth x estimated tokens per request / measured pod
//!     tok/s, inflated by KV pressure) already exceeds its remaining
//!     budget. The request was going to miss; rejecting it now costs
//!     zero prefill compute and returns a typed, retryable answer.
//!
//! The feasibility check for a tier only activates once every *lower*
//! tier is pressure-shed (its activation floor is the next tier down's
//! shed threshold). This keeps priority ordering invertible-free: an
//! Interactive request is never predictively shed at an instant where a
//! Batch request of equal-or-later deadline would be admitted — the
//! property `prop_overload_conservation` pins. Below the floor, a
//! doomed request is still caught by the engine's own dead-at-admission
//! drop, so conservation never depends on the gateway guessing right.
//!
//! The controller composes with — never replaces — the token-bucket
//! rate limiter: [`super::Gateway::dispatch`] runs the limiter first
//! (per-tenant quota), then admission (cluster overload), then routing.
//!
//! Everything here is a pure function of (config, now, request,
//! snapshots): same inputs, same verdict — the overload bench and the
//! proptests replay traces deterministically.

use super::router::PodSnapshot;
use super::view::fleet_pressure;
use crate::chaos::RejectReason;
use crate::sim::SimTime;
use crate::workload::{Request, Tier};

/// Admission thresholds and estimator knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Fleet pressure at/above which Batch-tier work is shed.
    pub batch_shed_pressure: f64,
    /// Fleet pressure at/above which Standard-tier work is shed.
    pub standard_shed_pressure: f64,
    /// Fleet pressure at/above which even Interactive work is shed (the
    /// brink: past this, admitting anything just lengthens the collapse).
    pub interactive_shed_pressure: f64,
    /// Assumed service demand per queued request (tokens) when estimating
    /// queue-ahead time — prompt prefill plus decode budget of a typical
    /// request; deliberately coarse, the signal is the *ordering*.
    pub est_tokens_per_request: f64,
    /// Serving rate assumed for pods that have not measured a throughput
    /// yet (fresh cluster), tokens/s.
    pub fallback_tokens_per_s: f64,
    /// Base Retry-After hint for pressure sheds, milliseconds; scales up
    /// with the pressure level so clients back off harder as the fleet
    /// saturates.
    pub base_retry_after_ms: u64,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            batch_shed_pressure: 0.60,
            standard_shed_pressure: 0.85,
            interactive_shed_pressure: 0.97,
            est_tokens_per_request: 64.0,
            fallback_tokens_per_s: 5_000.0,
            base_retry_after_ms: 250,
        }
    }
}

impl AdmissionConfig {
    /// Pressure at/above which `tier` is shed. Monotone in priority:
    /// lower tiers always shed at-or-before higher ones.
    pub fn shed_pressure(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Interactive => self.interactive_shed_pressure,
            Tier::Standard => self.standard_shed_pressure,
            Tier::Batch => self.batch_shed_pressure,
        }
    }

    /// Pressure at/above which the deadline-feasibility estimate applies
    /// to `tier`: the shed threshold of the tier below it, so predictive
    /// deadline sheds can never invert priority (every lower tier is
    /// already pressure-shed when this fires).
    fn feasibility_floor(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Interactive => self.standard_shed_pressure,
            Tier::Standard => self.batch_shed_pressure,
            Tier::Batch => 0.0,
        }
    }
}

/// A refused admission: the typed reason plus a Retry-After hint for the
/// HTTP surface (429 with backoff for sheds, immediate for dead-on-
/// arrival deadlines — retrying those without a new deadline is futile).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shed {
    pub reason: RejectReason,
    pub retry_after_ms: u64,
}

/// Admission outcomes by tier (index = [`tier_index`]), feeding the
/// `aibrix_admission_{admitted,shed}_total{tier,reason}` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionCounters {
    pub admitted: [u64; 3],
    /// Pressure sheds (`reason="admission_shed"`).
    pub shed_pressure: [u64; 3],
    /// Predictive + dead-on-arrival deadline sheds
    /// (`reason="deadline_exceeded"`).
    pub shed_deadline: [u64; 3],
}

impl AdmissionCounters {
    pub fn total_shed(&self) -> u64 {
        self.shed_pressure.iter().sum::<u64>() + self.shed_deadline.iter().sum::<u64>()
    }
}

/// Stable metrics index for a tier (Interactive first — it is the tier
/// operators alert on).
pub fn tier_index(tier: Tier) -> usize {
    match tier {
        Tier::Interactive => 0,
        Tier::Standard => 1,
        Tier::Batch => 2,
    }
}

/// The predictive admission controller. One per gateway; `evaluate` is
/// called after the rate limiter and before routing.
#[derive(Debug, Default)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    counters: AdmissionCounters,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController { cfg, counters: AdmissionCounters::default() }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Admission outcomes so far (metrics surface).
    pub fn counters(&self) -> &AdmissionCounters {
        &self.counters
    }

    /// Admit or shed one request against the current fleet snapshots.
    /// Deterministic: a pure function of (config, now, request, snaps)
    /// plus counter bookkeeping.
    pub fn evaluate(
        &mut self,
        now: SimTime,
        req: &Request,
        snaps: &[PodSnapshot],
    ) -> Result<(), Shed> {
        let ti = tier_index(req.tier);
        let pressure = fleet_pressure(snaps);
        if pressure >= self.cfg.shed_pressure(req.tier) {
            self.counters.shed_pressure[ti] += 1;
            return Err(Shed {
                reason: RejectReason::AdmissionShed,
                retry_after_ms: self.retry_after_ms(pressure),
            });
        }
        if let Some(deadline) = req.deadline {
            if deadline <= now {
                // Dead on arrival: no amount of scheduling meets it.
                self.counters.shed_deadline[ti] += 1;
                return Err(Shed { reason: RejectReason::DeadlineExceeded, retry_after_ms: 0 });
            }
            if pressure >= self.cfg.feasibility_floor(req.tier)
                && deadline.saturating_sub(now) < self.estimated_wait_us(snaps)
            {
                self.counters.shed_deadline[ti] += 1;
                return Err(Shed {
                    reason: RejectReason::DeadlineExceeded,
                    retry_after_ms: self.retry_after_ms(pressure),
                });
            }
        }
        self.counters.admitted[ti] += 1;
        Ok(())
    }

    /// Best-case queue-ahead service time across pods accepting new work,
    /// in µs: queued work (waiting + running, in estimated tokens) over
    /// the pod's measured serving rate, inflated by KV pressure (a
    /// near-full cache preempts and recomputes, so effective throughput
    /// sags). Unroutable fleet estimates infinite wait.
    fn estimated_wait_us(&self, snaps: &[PodSnapshot]) -> u64 {
        let mut best = u64::MAX;
        for s in snaps {
            if !s.accepts_new_work() {
                continue;
            }
            let queued = (s.stats.waiting + s.stats.running) as f64
                * self.cfg.est_tokens_per_request.max(1.0);
            let rate = if s.stats.tokens_per_s > 0.0 {
                s.stats.tokens_per_s
            } else {
                self.cfg.fallback_tokens_per_s.max(1.0)
            };
            let slowdown = 1.0 - s.stats.kv_utilization.clamp(0.0, 0.9);
            let wait = queued / rate / slowdown * 1e6;
            if wait.is_finite() {
                best = best.min(wait as u64);
            }
        }
        best
    }

    /// Retry-After grows with pressure: 1x the base just above a shed
    /// threshold, up to 5x at full saturation. Deterministic — no jitter
    /// (callers add their own).
    fn retry_after_ms(&self, pressure: f64) -> u64 {
        let scale = 1 + (pressure.clamp(0.0, 1.0) * 4.0) as u64;
        self.cfg.base_retry_after_ms.max(1).saturating_mul(scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineStats;

    fn req(tier: Tier, deadline: Option<SimTime>) -> Request {
        Request {
            id: 1,
            session: 0,
            tokens: vec![1; 16],
            output_len: 8,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline,
            tier,
        }
    }

    fn pod(pressure: f64, waiting: usize, tokens_per_s: f64) -> PodSnapshot {
        PodSnapshot {
            stats: EngineStats { pressure, waiting, tokens_per_s, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn tiers_shed_in_priority_order_as_pressure_rises() {
        let mut ac = AdmissionController::default();
        for (pressure, batch_ok, std_ok, int_ok) in [
            (0.30, true, true, true),
            (0.70, false, true, true),
            (0.90, false, false, true),
            (0.99, false, false, false),
        ] {
            let snaps = [pod(pressure, 0, 0.0)];
            assert_eq!(ac.evaluate(0, &req(Tier::Batch, None), &snaps).is_ok(), batch_ok);
            assert_eq!(ac.evaluate(0, &req(Tier::Standard, None), &snaps).is_ok(), std_ok);
            assert_eq!(
                ac.evaluate(0, &req(Tier::Interactive, None), &snaps).is_ok(),
                int_ok,
                "pressure {pressure}"
            );
        }
        let c = ac.counters();
        assert_eq!(c.admitted, [3, 2, 1]);
        assert_eq!(c.shed_pressure, [1, 2, 3]);
        assert_eq!(c.total_shed(), 6);
        // Pressure sheds carry a growing Retry-After hint.
        let shed = ac.evaluate(0, &req(Tier::Batch, None), &[pod(0.99, 0, 0.0)]).unwrap_err();
        assert_eq!(shed.reason, RejectReason::AdmissionShed);
        assert!(shed.retry_after_ms >= 250);
    }

    #[test]
    fn infeasible_deadline_sheds_predictively() {
        let mut ac = AdmissionController::default();
        // 10 queued requests x 64 tokens at 1000 tok/s = 640ms queue-ahead.
        let busy = [pod(0.0, 10, 1_000.0)];
        // Batch feasibility applies at any pressure: 100ms budget can't make it.
        let shed =
            ac.evaluate(0, &req(Tier::Batch, Some(100_000)), &busy).unwrap_err();
        assert_eq!(shed.reason, RejectReason::DeadlineExceeded);
        // A 2s budget clears the estimate.
        assert!(ac.evaluate(0, &req(Tier::Batch, Some(2_000_000)), &busy).is_ok());
        // Interactive feasibility is gated: below the Standard shed
        // threshold the same doomed budget is still admitted (the engine's
        // dead-at-admission drop is the backstop) — priority can never
        // invert against a lower tier.
        assert!(ac.evaluate(0, &req(Tier::Interactive, Some(100_000)), &busy).is_ok());
        let hot = [pod(0.90, 10, 1_000.0)];
        let shed =
            ac.evaluate(0, &req(Tier::Interactive, Some(100_000)), &hot).unwrap_err();
        assert_eq!(shed.reason, RejectReason::DeadlineExceeded);
        // Dead on arrival is always shed, any tier, any pressure.
        let idle = [pod(0.0, 0, 0.0)];
        let shed = ac.evaluate(500, &req(Tier::Interactive, Some(400)), &idle).unwrap_err();
        assert_eq!(shed.reason, RejectReason::DeadlineExceeded);
        assert_eq!(shed.retry_after_ms, 0, "expired deadline: backoff is futile");
        assert_eq!(ac.counters().shed_deadline, [2, 0, 1]);
    }

    #[test]
    fn kv_pressure_and_fallback_rate_shape_the_estimate() {
        let ac = AdmissionController::default();
        // No measured throughput: the fallback rate applies. 5 requests x
        // 64 tokens at 5000 tok/s = 64ms.
        let w = ac.estimated_wait_us(&[pod(0.0, 5, 0.0)]);
        assert_eq!(w, 64_000);
        // 80% KV utilization inflates the same queue 5x.
        let mut p = pod(0.0, 5, 0.0);
        p.stats.kv_utilization = 0.8;
        let w_hot = ac.estimated_wait_us(&[p]);
        assert_eq!(w_hot, 320_000);
        // Best pod wins: an idle replica makes the fleet estimate 0.
        let w_best = ac.estimated_wait_us(&[pod(0.9, 5, 0.0), pod(0.0, 0, 0.0)]);
        assert_eq!(w_best, 0);
        // No routable pod: infinite wait (every deadline infeasible).
        assert_eq!(ac.estimated_wait_us(&[]), u64::MAX);
    }

    #[test]
    fn unroutable_fleet_sheds_everything() {
        let mut ac = AdmissionController::default();
        // fleet_pressure of an empty/unready fleet is 1.0: even
        // Interactive sheds rather than queueing into the void.
        let shed = ac.evaluate(0, &req(Tier::Interactive, None), &[]).unwrap_err();
        assert_eq!(shed.reason, RejectReason::AdmissionShed);
    }
}
