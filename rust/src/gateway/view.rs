//! ClusterView — the unified routing signal plane.
//!
//! Before this layer, every entry point (sim harness, `aibrix serve`, the
//! autoscaler simulation, experiments, benches) hand-rolled its own
//! [`PodSnapshot`]s from whatever subset of signals it happened to have,
//! and `prefix_match_blocks` only ever saw engine-local caches — the
//! distributed KV pool (kvcache/pool.rs) was invisible to placement.
//! `ClusterView` is the single snapshot producer: it composes, per
//! request,
//!
//!   * **raw pod signals** — load/latency/KV stats, readiness, resident
//!     adapters, engine-local prefix matches — via [`PodSignalSource`]
//!     (implemented by the engine simulator, by counter-backed
//!     [`CounterPod`]s for the HTTP server, and by plain [`PodSignals`]
//!     values for tests);
//!   * **pool residency** — [`DistKvPool::residency`] per node, hashed
//!     with the same chain seed the serving path uses, so
//!     `prefix_match_blocks` / `pool_blocks_*` reflect *pool* state per
//!     node and the router can prefer the replica whose shard already
//!     holds the prompt's blocks;
//!   * **SLO targets** — from [`crate::optimizer::profiles::Slo`], turned
//!     into a per-pod latency-budget headroom signal;
//!   * **session stickiness** — a bounded session→pod table maintained by
//!     [`ClusterView::note_route`], so multi-turn chats keep KV locality
//!     even when prefix caches churn.
//!
//! The snapshot is a pure function of (config, pod signals, pool state,
//! session table): same inputs ⇒ identical `PodSnapshot` vector, whatever
//! entry point produced them (property-tested in `tests/cluster_view.rs`).

use std::collections::{HashMap, VecDeque};

use super::router::PodSnapshot;
use crate::diagnostics::Action;
use crate::engine::prefix::{prompt_block_keys_seeded_into, BlockKey};
use crate::engine::{EngineSim, EngineStats};
use crate::kvcache::DistKvPool;
use crate::optimizer::profiles::Slo;
use crate::sim::SimTime;
use crate::workload::Request;

/// Replica health, the state machine driving drain/cordon decisions.
/// Ordered by badness: the machine only escalates (except an explicit
/// [`ClusterView::recover_pod`]), so `max` composes verdicts from
/// independent detectors without flapping.
///
/// * `Healthy` — full service.
/// * `Degraded` — suspect (straggling, throttle verdicts): serves, but the
///   health scorer steers new work away when better pods exist.
/// * `Draining` — confirmed bad (DrainAndCordon verdict): finishes its
///   in-flight work but receives **no** new requests; sticky sessions are
///   re-homed.
/// * `Cordoned` — out of rotation entirely (drained, or dead via missed
///   heartbeats): excluded from routing like a not-ready pod.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum HealthState {
    #[default]
    Healthy,
    Degraded,
    Draining,
    Cordoned,
}

impl HealthState {
    /// May this pod be handed *new* work? (Draining pods only finish what
    /// they already hold; Cordoned pods are out of rotation.)
    pub fn accepts_new_work(&self) -> bool {
        matches!(self, HealthState::Healthy | HealthState::Degraded)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Draining => "draining",
            HealthState::Cordoned => "cordoned",
        }
    }
}

/// Detection thresholds for the health state machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthPolicy {
    /// Consecutive not-ready observations (missed heartbeats) before a
    /// pod is declared dead and Cordoned.
    pub missed_to_cordon: u32,
    /// A ready pod whose mean latency exceeds the best ready pod's by this
    /// factor is a straggler (Degraded).
    pub straggler_factor: f64,
}

impl Default for HealthPolicy {
    fn default() -> HealthPolicy {
        HealthPolicy { missed_to_cordon: 3, straggler_factor: 4.0 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct PodHealth {
    state: HealthState,
    /// Consecutive not-ready observations.
    missed: u32,
    cordoned_at: Option<SimTime>,
}

/// Per-pod health records plus the transition log. Owned by
/// [`ClusterView`]; fed by heartbeat/straggler detection on every
/// snapshot and by external `diagnostics::diagnose` verdicts via
/// [`ClusterView::apply_diagnosis`].
#[derive(Debug, Default)]
pub struct HealthTracker {
    policy: HealthPolicy,
    pods: Vec<PodHealth>,
    /// (time, pod, entered state) — every state change, in order.
    transitions: Vec<(SimTime, usize, HealthState)>,
}

impl HealthTracker {
    pub fn new(policy: HealthPolicy) -> HealthTracker {
        HealthTracker { policy, ..Default::default() }
    }

    fn ensure(&mut self, pod: usize) {
        if pod >= self.pods.len() {
            self.pods.resize(pod + 1, PodHealth::default());
        }
    }

    pub fn state(&self, pod: usize) -> HealthState {
        self.pods.get(pod).map(|p| p.state).unwrap_or_default()
    }

    /// When the pod entered Cordoned (detection-latency observability).
    pub fn cordoned_at(&self, pod: usize) -> Option<SimTime> {
        self.pods.get(pod).and_then(|p| p.cordoned_at)
    }

    /// Full transition history: (time, pod, entered state).
    pub fn transitions(&self) -> &[(SimTime, usize, HealthState)] {
        &self.transitions
    }

    /// Escalate `pod` to at least `to`; true if the state changed.
    fn escalate(&mut self, now: SimTime, pod: usize, to: HealthState) -> bool {
        self.ensure(pod);
        let Some(p) = self.pods.get_mut(pod) else { return false };
        if to <= p.state {
            return false;
        }
        p.state = to;
        if to == HealthState::Cordoned {
            p.cordoned_at = Some(now);
        }
        self.transitions.push((now, pod, to));
        true
    }

    /// Feed one `diagnostics::diagnose` verdict. Monitor-grade findings
    /// leave routing alone; throttle verdicts mark the pod Degraded;
    /// drain/replace verdicts start the drain. Returns true if the pod
    /// newly stopped accepting work (caller re-homes its sessions).
    fn apply_diagnosis(&mut self, now: SimTime, pod: usize, action: Action) -> bool {
        match action {
            Action::Monitor => false,
            Action::ThrottleWorkload => {
                self.escalate(now, pod, HealthState::Degraded);
                false
            }
            Action::DrainAndCordon | Action::ReplaceDevice => {
                self.escalate(now, pod, HealthState::Draining)
            }
        }
    }

    /// One heartbeat/straggler sweep over the fleet's raw signals.
    /// Returns the pods that newly stopped accepting work this sweep.
    fn observe(&mut self, now: SimTime, sigs: &[PodSignals]) -> Vec<usize> {
        // Best (lowest) positive mean latency among ready pods: the
        // straggler baseline. One slow pod alone is its own baseline and
        // never flags; detection needs a healthy peer to compare against.
        let mut best = f64::INFINITY;
        for s in sigs {
            let l = s.stats.avg_latency_us;
            if s.ready && l > 0.0 && l < best {
                best = l;
            }
        }
        let mut newly_out = Vec::new();
        for s in sigs {
            self.ensure(s.pod);
            let straggler = s.ready
                && best.is_finite()
                && s.stats.avg_latency_us > self.policy.straggler_factor * best;
            let drained_idle = s.stats.waiting + s.stats.running == 0;
            let Some(p) = self.pods.get_mut(s.pod) else { continue };
            if s.ready {
                p.missed = 0;
            } else {
                p.missed = p.missed.saturating_add(1);
            }
            let dead = p.missed >= self.policy.missed_to_cordon;
            let was_accepting = p.state.accepts_new_work();
            if dead {
                self.escalate(now, s.pod, HealthState::Cordoned);
            } else if p.state == HealthState::Draining && drained_idle {
                // Drain complete: nothing in flight, take it out.
                self.escalate(now, s.pod, HealthState::Cordoned);
            } else if straggler {
                self.escalate(now, s.pod, HealthState::Degraded);
            }
            if was_accepting && !self.state(s.pod).accepts_new_work() {
                newly_out.push(s.pod);
            }
        }
        newly_out
    }

    /// Put a repaired/replaced pod back in rotation.
    fn recover(&mut self, now: SimTime, pod: usize) {
        self.ensure(pod);
        let Some(p) = self.pods.get_mut(pod) else { return };
        if p.state != HealthState::Healthy {
            p.state = HealthState::Healthy;
            p.missed = 0;
            p.cordoned_at = None;
            self.transitions.push((now, pod, HealthState::Healthy));
        }
    }
}

/// Configuration of the signal plane.
#[derive(Debug, Clone)]
pub struct ClusterViewConfig {
    /// Tokens per content-addressed block — must match the engines' block
    /// size (and the pool's `block_tokens`) or residency probes miss.
    pub block_size: usize,
    /// Chain-hash seed: 0 for the simulator's unseeded chain,
    /// [`crate::engine::prefix::model_chain_seed`]-derived for the real
    /// serving path (ask the `EnginePool` hook via `chain_seed()`).
    pub chain_seed: BlockKey,
    /// SLO targets feeding the slo-headroom signal.
    pub slo: Slo,
    /// Bound on tracked sessions; oldest-by-first-appearance evicts first.
    pub session_capacity: usize,
    /// Idle TTL for sticky sessions, µs of sim/pool time. A session not
    /// re-routed for this long stops pinning the affinity scorer (its
    /// engine-side KV is long since evicted anyway). `None` = never
    /// expire (the pre-TTL behavior; capacity eviction still applies).
    pub session_ttl: Option<SimTime>,
    /// Heartbeat/straggler thresholds for the health state machine.
    pub health: HealthPolicy,
}

impl Default for ClusterViewConfig {
    fn default() -> ClusterViewConfig {
        ClusterViewConfig {
            block_size: 16,
            chain_seed: 0,
            slo: Slo::default(),
            session_capacity: 4096,
            session_ttl: None,
            health: HealthPolicy::default(),
        }
    }
}

impl ClusterViewConfig {
    /// Defaults with the operator env knobs applied: `AIBRIX_SLO_TTFT_MS`,
    /// `AIBRIX_SLO_ITL_MS`, `AIBRIX_SESSION_CAP`, `AIBRIX_SESSION_TTL_MS`.
    /// Garbage values are hard errors, never silent defaults.
    pub fn from_env() -> Result<ClusterViewConfig, String> {
        let mut cfg = ClusterViewConfig::default();
        if let Ok(v) = std::env::var("AIBRIX_SLO_TTFT_MS") {
            cfg.slo.ttft_ms = v
                .parse()
                .map_err(|_| format!("AIBRIX_SLO_TTFT_MS={v:?} is not a number"))?;
        }
        if let Ok(v) = std::env::var("AIBRIX_SLO_ITL_MS") {
            cfg.slo.itl_ms = v
                .parse()
                .map_err(|_| format!("AIBRIX_SLO_ITL_MS={v:?} is not a number"))?;
        }
        if let Ok(v) = std::env::var("AIBRIX_SESSION_CAP") {
            cfg.session_capacity = v
                .parse()
                .map_err(|_| format!("AIBRIX_SESSION_CAP={v:?} is not a number"))?;
        }
        if let Ok(v) = std::env::var("AIBRIX_SESSION_TTL_MS") {
            let ms: u64 = v
                .parse()
                .map_err(|_| format!("AIBRIX_SESSION_TTL_MS={v:?} is not a number"))?;
            cfg.session_ttl = Some(ms.saturating_mul(1000));
        }
        Ok(cfg)
    }
}

/// One pod's raw signals, before pool/session/SLO enrichment.
#[derive(Debug, Clone, PartialEq)]
pub struct PodSignals {
    pub pod: usize,
    /// Hosting node (pool colocation identity).
    pub node: u64,
    pub ready: bool,
    pub stats: EngineStats,
    /// Leading prompt blocks held by the pod's engine-local prefix cache.
    pub local_match_blocks: usize,
    pub resident_adapters: Vec<String>,
}

/// Anything that can report one pod's raw routing signals for a request
/// whose prompt hashes to `keys`.
pub trait PodSignalSource {
    fn signals(&mut self, now: SimTime, keys: &[BlockKey]) -> PodSignals;
}

impl PodSignalSource for EngineSim {
    fn signals(&mut self, now: SimTime, keys: &[BlockKey]) -> PodSignals {
        PodSignals {
            pod: self.id,
            node: self.node,
            ready: !self.is_failed(),
            stats: self.stats(now),
            local_match_blocks: self.prefix_match_blocks(keys),
            resident_adapters: self.resident_adapters().to_vec(),
        }
    }
}

/// Pre-assembled signals pass through unchanged (tests, replays).
impl PodSignalSource for PodSignals {
    fn signals(&mut self, _now: SimTime, _keys: &[BlockKey]) -> PodSignals {
        self.clone()
    }
}

/// Counter-backed pod for entry points without an engine simulator —
/// `aibrix serve` mirrors its scheduler's queue split (waiting vs
/// running) and KV pressure per replica; every other raw signal is
/// neutral and the view supplies pool/session/SLO.
#[derive(Debug, Clone)]
pub struct CounterPod {
    pub pod: usize,
    pub node: u64,
    pub ready: bool,
    /// Enqueued-not-yet-scheduled requests (admission backlog — the
    /// signal that predicts queueing delay).
    pub waiting: usize,
    /// Requests holding cache rows right now (prefilling or decoding).
    pub running: usize,
    /// KV cache utilization in `[0, 1]` — the memory-pressure signal the
    /// scorers and autoscaler read (preemption risk when near 1).
    pub kv_pressure: f64,
    /// Engine-published overload pressure in `[0, 1]` (max of KV and
    /// queue-depth components) — the backpressure signal admission reads.
    pub pressure: f64,
    /// Measured rolling SLO attainment: fraction of the engine's recent
    /// completions that met their TTFT/ITL budgets.
    pub slo_attainment: f64,
    /// Completions inside the attainment window (0 = no history yet).
    pub slo_samples: u64,
}

impl CounterPod {
    /// Total unfinished requests (back-compat load measure).
    pub fn inflight(&self) -> usize {
        self.waiting + self.running
    }
}

impl PodSignalSource for CounterPod {
    fn signals(&mut self, _now: SimTime, _keys: &[BlockKey]) -> PodSignals {
        PodSignals {
            pod: self.pod,
            node: self.node,
            ready: self.ready,
            stats: EngineStats {
                waiting: self.waiting,
                running: self.running,
                kv_utilization: self.kv_pressure,
                pressure: self.pressure,
                slo_attainment: self.slo_attainment,
                slo_samples: self.slo_samples,
                ..EngineStats::default()
            },
            local_match_blocks: 0,
            resident_adapters: Vec::new(),
        }
    }
}

/// Fleet-wide KV memory pressure: mean `kv_utilization` over pods that
/// accept new work (the autoscaler's §4 capacity signal — scale out as
/// the fleet nears preemption territory, whatever the queue depths say).
pub fn fleet_kv_pressure(snaps: &[PodSnapshot]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for s in snaps {
        if s.ready && s.health.accepts_new_work() {
            sum += s.stats.kv_utilization;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Headroom vs the SLO in `[0, 1]`: the pod's *measured* rolling SLO
/// attainment — the fraction of its recent completions that met their
/// TTFT/ITL budgets, straight from the engine's attainment window. 1 =
/// everything on target, 0 = everything blown. A pod with no recent
/// completions (fresh cluster, idle pod) reports full headroom.
///
/// Replaces the old latency-*proxy* (mean end-to-end latency vs this
/// request's budget), which confused long-decode traffic with SLO risk
/// and never saw TTFT at all. Feeds both the slo-headroom scorer and the
/// gateway admission estimator.
pub fn slo_headroom(stats: &EngineStats) -> f64 {
    if stats.slo_samples == 0 {
        return 1.0;
    }
    let h = stats.slo_attainment.clamp(0.0, 1.0);
    if h.is_finite() {
        h
    } else {
        0.0
    }
}

/// Fleet-wide overload pressure: the *maximum* engine-published pressure
/// over pods accepting new work. Max, not mean — one saturated replica is
/// where the next misrouted request dies, and admission must tighten on
/// the worst case. Empty/unroutable fleet reports pressure 1.0 (nothing
/// can serve: shed).
pub fn fleet_pressure(snaps: &[PodSnapshot]) -> f64 {
    let mut worst: Option<f64> = None;
    for s in snaps {
        if s.ready && s.health.accepts_new_work() {
            let p = s.stats.pressure.clamp(0.0, 1.0);
            worst = Some(worst.map_or(p, |w: f64| w.max(p)));
        }
    }
    worst.unwrap_or(1.0)
}

/// Bounded session → pod table. Eviction is FIFO by *first appearance*:
/// re-routing an existing session updates its pod (and idle timestamp)
/// without re-queueing it, so the table stays O(capacity) and fully
/// deterministic. Entries also expire after an idle TTL (lazily, on the
/// snapshot/sweep that first observes them stale).
#[derive(Debug)]
struct SessionTable {
    /// session → (pod, last touch time).
    map: HashMap<u64, (usize, SimTime)>,
    order: VecDeque<u64>,
    capacity: usize,
}

impl SessionTable {
    fn new(capacity: usize) -> SessionTable {
        SessionTable { map: HashMap::new(), order: VecDeque::new(), capacity }
    }

    fn note(&mut self, session: u64, pod: usize, now: SimTime) {
        if self.capacity == 0 {
            return;
        }
        use std::collections::hash_map::Entry;
        match self.map.entry(session) {
            Entry::Occupied(mut e) => {
                e.insert((pod, now));
            }
            Entry::Vacant(v) => {
                v.insert((pod, now));
                self.order.push_back(session);
            }
        }
        while self.order.len() > self.capacity {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
    }

    fn pod_of(&self, session: u64) -> Option<usize> {
        self.map.get(&session).map(|&(pod, _)| pod)
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    /// Eagerly forget one finished session (the request-level
    /// `end_session` signal): the slot frees immediately instead of
    /// waiting for TTL or capacity pressure.
    fn end(&mut self, session: u64) {
        if self.map.remove(&session).is_some() {
            self.order.retain(|s| *s != session);
        }
    }

    /// Drop every session idle for `ttl` or longer (last touch at or
    /// before `now - ttl`). Lazy: called from snapshot/sweep, so an
    /// expired session stops pinning the affinity scorer on the next
    /// routing decision after its TTL elapses.
    fn purge_expired(&mut self, now: SimTime, ttl: SimTime) {
        self.map.retain(|_, &mut (_, touch)| now.saturating_sub(touch) < ttl);
        let map = &self.map;
        self.order.retain(|s| map.contains_key(s));
    }

    /// Forget every session pinned to `pod` (it stopped accepting work):
    /// a sticky session must never pin to a corpse — its next request
    /// re-routes freely and re-sticks wherever it lands.
    fn purge_pod(&mut self, pod: usize) {
        self.map.retain(|_, (p, _)| *p != pod);
        let map = &self.map;
        self.order.retain(|s| map.contains_key(s));
    }
}

/// The unified snapshot producer. One instance per routing loop (harness
/// run, server process, bench): it owns the session table and a key
/// scratch buffer, and turns raw pod signals + pool state into the
/// [`PodSnapshot`] vector the scoring pipeline consumes.
pub struct ClusterView {
    cfg: ClusterViewConfig,
    sessions: SessionTable,
    health: HealthTracker,
    /// Scratch: the request's block-key chain, reused across requests.
    keys: Vec<BlockKey>,
    /// Scratch: raw signals gathered before the health sweep.
    sigs: Vec<PodSignals>,
    /// Latest `now` seen by snapshot/sweep — stamps session touches so
    /// `note_route`'s signature stays clock-free.
    now_hint: SimTime,
}

impl ClusterView {
    pub fn new(cfg: ClusterViewConfig) -> ClusterView {
        let sessions = SessionTable::new(cfg.session_capacity);
        let health = HealthTracker::new(cfg.health);
        ClusterView { cfg, sessions, health, keys: Vec::new(), sigs: Vec::new(), now_hint: 0 }
    }

    pub fn config(&self) -> &ClusterViewConfig {
        &self.cfg
    }

    /// The health state machine's records (read-only observability).
    pub fn health(&self) -> &HealthTracker {
        &self.health
    }

    /// Feed one `diagnostics::diagnose` verdict for `pod` into the health
    /// machine. If the verdict takes the pod out of new-work rotation, its
    /// sticky sessions are invalidated on the spot.
    pub fn apply_diagnosis(&mut self, now: SimTime, pod: usize, action: Action) {
        if self.health.apply_diagnosis(now, pod, action) {
            self.sessions.purge_pod(pod);
        }
    }

    /// Put a repaired/replaced pod back into rotation.
    pub fn recover_pod(&mut self, now: SimTime, pod: usize) {
        self.health.recover(now, pod);
    }

    /// Record a routing decision for session stickiness. Call on every
    /// `Decision::Route`. Session 0 means *stateless* repo-wide (the
    /// server's sessionless requests, generators start real ids at 1) and
    /// is never tracked — so stray session-less traffic can never herd
    /// onto one pod through a phantom shared session.
    pub fn note_route(&mut self, session: u64, pod: usize) {
        if session != 0 {
            self.sessions.note(session, pod, self.now_hint);
        }
    }

    /// Eagerly drop a finished session's stickiness (the request carried
    /// `end_session`): the slot frees now, instead of waiting for the
    /// idle TTL or capacity eviction. No-op for the stateless session 0.
    pub fn end_session(&mut self, session: u64) {
        if session != 0 {
            self.sessions.end(session);
        }
    }

    /// Pod the session last routed to, if still tracked (None for the
    /// stateless session 0).
    pub fn session_pod(&self, session: u64) -> Option<usize> {
        if session == 0 {
            return None;
        }
        self.sessions.pod_of(session)
    }

    /// Sessions currently tracked (observability).
    pub fn tracked_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Run one heartbeat/straggler sweep over the fleet without building
    /// snapshots — the harness's periodic diagnostics tick, so detection
    /// (and the Draining→Cordoned hand-off once in-flight work drains)
    /// does not depend on arrival traffic. Sessions pinned to pods that
    /// stop accepting work are purged, exactly as in [`ClusterView::snapshot`].
    pub fn sweep<S: PodSignalSource>(&mut self, now: SimTime, pods: &mut [S]) {
        self.now_hint = now;
        if let Some(ttl) = self.cfg.session_ttl {
            self.sessions.purge_expired(now, ttl);
        }
        self.sigs.clear();
        for p in pods.iter_mut() {
            let s = p.signals(now, &[]);
            self.sigs.push(s);
        }
        for pod in self.health.observe(now, &self.sigs) {
            self.sessions.purge_pod(pod);
        }
    }

    /// Build the per-request snapshot vector: one [`PodSnapshot`] per
    /// signal source, in order. `pool` is the distributed KV pool when one
    /// is wired in — its residency probe feeds `pool_blocks_*` and lifts
    /// `prefix_match_blocks` to the max of engine-local and pool-local
    /// state, making the pool a placement signal.
    pub fn snapshot<S: PodSignalSource>(
        &mut self,
        now: SimTime,
        req: &Request,
        pods: &mut [S],
        pool: Option<&DistKvPool>,
    ) -> Vec<PodSnapshot> {
        // Expire idle sessions first: a stale pin must not influence this
        // request's stickiness.
        self.now_hint = now;
        if let Some(ttl) = self.cfg.session_ttl {
            self.sessions.purge_expired(now, ttl);
        }
        // Hash the prompt chain once per request into the scratch buffer —
        // the same walk the engines' admission lookups use, by definition.
        let bs = self.cfg.block_size.max(1);
        prompt_block_keys_seeded_into(self.cfg.chain_seed, &req.tokens, bs, &mut self.keys);
        let prompt_blocks = self.keys.len().max(1);

        // Gather raw signals, then run the heartbeat/straggler sweep over
        // the whole fleet (straggler detection is relative to peers, so it
        // needs every pod's stats at once). Pods that just stopped
        // accepting work lose their sticky sessions before stickiness is
        // consulted — a session must never pin to a corpse.
        self.sigs.clear();
        for p in pods.iter_mut() {
            let s = p.signals(now, &self.keys);
            self.sigs.push(s);
        }
        for pod in self.health.observe(now, &self.sigs) {
            self.sessions.purge_pod(pod);
        }
        let sticky = self.session_pod(req.session);

        let mut out = Vec::with_capacity(pods.len());
        for s in self.sigs.drain(..) {
            let health = self.health.state(s.pod);
            let res = match pool {
                Some(pool) => pool.residency(now, s.node, &self.keys),
                None => Default::default(),
            };
            out.push(PodSnapshot {
                pod: s.pod,
                // A Cordoned pod is out of rotation outright, exactly like
                // a pod that never heartbeated.
                ready: s.ready && health != HealthState::Cordoned,
                health,
                prefix_match_blocks: s.local_match_blocks.max(res.local_blocks),
                prompt_blocks,
                pool_blocks_local: res.local_blocks,
                pool_blocks_total: res.visible_blocks,
                pool_blocks_cold: res.cold_blocks,
                session_match: sticky == Some(s.pod),
                slo_headroom: slo_headroom(&s.stats),
                resident_adapters: s.resident_adapters,
                stats: s.stats,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvPoolConfig;

    fn req(tokens: usize, session: u64) -> Request {
        Request {
            id: 0,
            session,
            tokens: (0..tokens as u32).collect(),
            output_len: 8,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: Default::default(),
        }
    }

    fn counter_pods(n: usize) -> Vec<CounterPod> {
        (0..n)
            .map(|i| CounterPod {
                pod: i,
                node: i as u64,
                ready: true,
                waiting: i,
                running: 0,
                kv_pressure: 0.0,
                pressure: 0.0,
                slo_attainment: 1.0,
                slo_samples: 0,
            })
            .collect()
    }

    #[test]
    fn snapshot_is_total_and_ordered() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(3);
        let snaps = view.snapshot(0, &req(64, 0), &mut pods, None);
        assert_eq!(snaps.len(), 3);
        for (i, s) in snaps.iter().enumerate() {
            assert_eq!(s.pod, i);
            assert_eq!(s.stats.waiting, i);
            assert_eq!(s.prompt_blocks, 4);
            assert_eq!(s.pool_blocks_total, 0);
            assert!(!s.session_match);
        }
    }

    #[test]
    fn session_table_sticks_and_bounds() {
        let cfg = ClusterViewConfig { session_capacity: 2, ..Default::default() };
        let mut view = ClusterView::new(cfg);
        view.note_route(1, 0);
        view.note_route(2, 1);
        let mut pods = counter_pods(2);
        let snaps = view.snapshot(0, &req(16, 2), &mut pods, None);
        assert!(!snaps[0].session_match);
        assert!(snaps[1].session_match);
        // Re-noting an existing session updates in place (no eviction).
        view.note_route(1, 1);
        assert_eq!(view.session_pod(1), Some(1));
        assert_eq!(view.tracked_sessions(), 2);
        // A third session evicts the oldest (session 1: first appearance).
        view.note_route(3, 0);
        assert_eq!(view.tracked_sessions(), 2);
        assert_eq!(view.session_pod(1), None, "oldest session evicted");
        assert_eq!(view.session_pod(2), Some(1));
        assert_eq!(view.session_pod(3), Some(0));
    }

    #[test]
    fn pool_residency_feeds_prefix_and_pool_signals() {
        use crate::engine::ExternalKv;
        let mut pool = DistKvPool::new(KvPoolConfig::new(
            vec![(0, 1 << 30), (1, 1 << 30)],
            1024,
            16,
        ));
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let r = req(64, 0); // 4 full blocks
        // Insert the prompt's first 3 block keys as node 0 (the view and
        // the pool must agree on the chain).
        let keys = crate::engine::prefix::prompt_block_keys(&r.tokens, 16);
        pool.insert(0, 0, &keys[..3], 16);
        let mut pods = counter_pods(2);
        // Past the visibility delay: both pods see 3 blocks, only pod 0
        // owns them.
        let snaps = view.snapshot(100_000, &r, &mut pods, Some(&pool));
        assert_eq!(snaps[0].pool_blocks_local, 3);
        assert_eq!(snaps[0].pool_blocks_total, 3);
        assert_eq!(snaps[0].prefix_match_blocks, 3, "pool feeds the prefix signal");
        assert_eq!(snaps[1].pool_blocks_local, 0);
        assert_eq!(snaps[1].pool_blocks_total, 3);
        assert_eq!(snaps[1].prefix_match_blocks, 0);
        assert!(snaps[0].pool_hit_fraction() > snaps[1].pool_hit_fraction());
    }

    #[test]
    fn slo_headroom_reports_measured_attainment() {
        let mut stats = EngineStats::default();
        assert_eq!(slo_headroom(&stats), 1.0, "no history = full headroom");
        // High mean latency alone no longer dents headroom — only *missed*
        // SLOs do (the old proxy punished long-decode traffic).
        stats.avg_latency_us = 30_000_000.0;
        assert_eq!(slo_headroom(&stats), 1.0);
        stats.slo_samples = 10;
        stats.slo_attainment = 0.7;
        assert!((slo_headroom(&stats) - 0.7).abs() < 1e-12);
        stats.slo_attainment = 2.0; // malformed publisher: clamp
        assert_eq!(slo_headroom(&stats), 1.0);
        stats.slo_attainment = 0.0;
        assert_eq!(slo_headroom(&stats), 0.0);
    }

    #[test]
    fn fleet_pressure_takes_the_worst_routable_pod() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(3);
        pods[0].pressure = 0.2;
        pods[1].pressure = 0.9;
        pods[2].pressure = 1.0;
        pods[2].ready = false; // out of rotation: its pressure is moot
        let snaps = view.snapshot(0, &req(16, 0), &mut pods, None);
        assert!((fleet_pressure(&snaps) - 0.9).abs() < 1e-12);
        assert_eq!(fleet_pressure(&[]), 1.0, "no routable pod = fully shed");
    }

    #[test]
    fn diagnosis_drives_healthy_degraded_draining_cordoned() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(2);
        pods[1].waiting = 1;
        pods[1].running = 2;
        assert_eq!(view.health().state(1), HealthState::Healthy);
        // Throttle verdict: Degraded, still routable.
        view.apply_diagnosis(10, 1, Action::ThrottleWorkload);
        assert_eq!(view.health().state(1), HealthState::Degraded);
        let snaps = view.snapshot(20, &req(16, 0), &mut pods, None);
        assert!(snaps[1].ready, "degraded pods still serve");
        assert!(snaps[1].health.accepts_new_work());
        // Drain verdict: Draining — stays ready (finishes work) but stops
        // accepting new requests.
        view.apply_diagnosis(30, 1, Action::DrainAndCordon);
        assert_eq!(view.health().state(1), HealthState::Draining);
        let snaps = view.snapshot(40, &req(16, 0), &mut pods, None);
        assert!(snaps[1].ready);
        assert!(!snaps[1].health.accepts_new_work());
        // In-flight work drains to zero: the sweep cordons it.
        pods[1].waiting = 0;
        pods[1].running = 0;
        let snaps = view.snapshot(50, &req(16, 0), &mut pods, None);
        assert_eq!(view.health().state(1), HealthState::Cordoned);
        assert!(!snaps[1].ready, "cordoned pods are excluded outright");
        assert_eq!(view.health().cordoned_at(1), Some(50));
        // Verdicts never de-escalate; explicit recovery does.
        view.apply_diagnosis(60, 1, Action::Monitor);
        assert_eq!(view.health().state(1), HealthState::Cordoned);
        view.recover_pod(70, 1);
        assert_eq!(view.health().state(1), HealthState::Healthy);
        let last = view.health().transitions().last().copied();
        assert_eq!(last, Some((70, 1, HealthState::Healthy)));
    }

    #[test]
    fn missed_heartbeats_cordon_a_dead_pod() {
        let cfg = ClusterViewConfig {
            health: HealthPolicy { missed_to_cordon: 3, ..Default::default() },
            ..Default::default()
        };
        let mut view = ClusterView::new(cfg);
        let mut pods = counter_pods(2);
        pods[0].ready = false; // died
        for t in 1..=2u64 {
            view.snapshot(t, &req(16, 0), &mut pods, None);
            assert_ne!(view.health().state(0), HealthState::Cordoned, "sweep {t}: not yet");
        }
        view.snapshot(3, &req(16, 0), &mut pods, None);
        assert_eq!(view.health().state(0), HealthState::Cordoned, "third miss cordons");
        assert_eq!(view.health().cordoned_at(0), Some(3));
        // A flapping pod that comes back before the threshold never trips.
        let mut v2 = ClusterView::new(ClusterViewConfig::default());
        let mut p2 = counter_pods(1);
        for t in 0..10u64 {
            p2[0].ready = t % 2 == 0;
            v2.snapshot(t, &req(16, 0), &mut p2, None);
        }
        assert_eq!(v2.health().state(0), HealthState::Healthy);
    }

    #[test]
    fn straggler_peer_detection_degrades() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mk = |pod: usize, lat: f64| PodSignals {
            pod,
            node: pod as u64,
            ready: true,
            stats: EngineStats { avg_latency_us: lat, waiting: 1, ..Default::default() },
            local_match_blocks: 0,
            resident_adapters: Vec::new(),
        };
        // Pod 1 is 10x slower than its best peer: straggler.
        let mut pods = vec![mk(0, 10_000.0), mk(1, 100_000.0)];
        view.snapshot(5, &req(16, 0), &mut pods, None);
        assert_eq!(view.health().state(0), HealthState::Healthy);
        assert_eq!(view.health().state(1), HealthState::Degraded);
        // A lone slow pod is its own baseline — never flagged.
        let mut view2 = ClusterView::new(ClusterViewConfig::default());
        let mut lone = vec![mk(0, 500_000.0)];
        view2.snapshot(5, &req(16, 0), &mut lone, None);
        assert_eq!(view2.health().state(0), HealthState::Healthy);
    }

    #[test]
    fn sticky_sessions_never_pin_to_a_drained_pod() {
        // Regression (satellite): SessionTable entries pointing at a pod
        // that stops accepting work must be invalidated — before this, a
        // sticky session kept routing at a corpse forever.
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(3);
        pods.iter_mut().for_each(|p| p.waiting = 1);
        view.note_route(7, 1);
        view.note_route(8, 2);
        assert_eq!(view.session_pod(7), Some(1));
        // Drain verdict for pod 1: its sessions purge immediately.
        view.apply_diagnosis(10, 1, Action::DrainAndCordon);
        assert_eq!(view.session_pod(7), None, "session re-homed off the draining pod");
        assert_eq!(view.session_pod(8), Some(2), "innocent sessions untouched");
        let snaps = view.snapshot(20, &req(16, 7), &mut pods, None);
        assert!(snaps.iter().all(|s| !s.session_match), "no stale stickiness");
        // Dead-pod path: missed heartbeats cordon pod 2 and purge its
        // sessions through the sweep as well.
        pods[2].ready = false;
        for t in 21..=23u64 {
            view.snapshot(t, &req(16, 0), &mut pods, None);
        }
        assert_eq!(view.health().state(2), HealthState::Cordoned);
        assert_eq!(view.session_pod(8), None, "dead pod's session purged");
        // The freed session re-sticks wherever it routes next.
        view.note_route(8, 0);
        assert_eq!(view.session_pod(8), Some(0));
    }

    #[test]
    fn session_ttl_expires_idle_sessions() {
        let cfg = ClusterViewConfig { session_ttl: Some(1_000), ..Default::default() };
        let mut view = ClusterView::new(cfg);
        let mut pods = counter_pods(2);
        // Establish "now" so the touch timestamp is meaningful.
        view.snapshot(100, &req(16, 0), &mut pods, None);
        view.note_route(7, 1);
        // Still inside the TTL: sticks.
        let snaps = view.snapshot(1_000, &req(16, 7), &mut pods, None);
        assert!(snaps[1].session_match, "fresh session sticks");
        // Touch via re-route keeps it alive past the original deadline.
        view.note_route(7, 1);
        let snaps = view.snapshot(1_900, &req(16, 7), &mut pods, None);
        assert!(snaps[1].session_match, "re-route refreshed the TTL");
        // Idle past the TTL: the next snapshot purges before stickiness.
        let snaps = view.snapshot(3_000, &req(16, 7), &mut pods, None);
        assert!(snaps.iter().all(|s| !s.session_match), "expired session unpins");
        assert_eq!(view.session_pod(7), None);
        assert_eq!(view.tracked_sessions(), 0);
        // Sweeps expire too (no request traffic needed). Touch is the
        // last snapshot's now (3_000).
        view.note_route(8, 0);
        view.sweep(3_500, &mut pods);
        assert_eq!(view.session_pod(8), Some(0), "inside TTL: survives the sweep");
        view.sweep(10_000, &mut pods);
        assert_eq!(view.session_pod(8), None, "idle session expired by sweep");
        // No TTL configured: sessions never expire by idling.
        let mut forever = ClusterView::new(ClusterViewConfig::default());
        forever.note_route(9, 1);
        forever.sweep(u64::MAX, &mut pods);
        assert_eq!(forever.session_pod(9), Some(1));
    }

    #[test]
    fn end_session_frees_slot_eagerly() {
        let cfg = ClusterViewConfig { session_capacity: 2, ..Default::default() };
        let mut view = ClusterView::new(cfg);
        view.note_route(1, 0);
        view.note_route(2, 1);
        assert_eq!(view.tracked_sessions(), 2);
        // Explicit end: the slot frees immediately.
        view.end_session(1);
        assert_eq!(view.session_pod(1), None, "ended session unpins");
        assert_eq!(view.tracked_sessions(), 1);
        // FIFO-cap interaction: the freed slot means a new session no
        // longer evicts the survivor (pre-fix, session 2 — now oldest —
        // would have been pushed out).
        view.note_route(3, 0);
        assert_eq!(view.tracked_sessions(), 2);
        assert_eq!(view.session_pod(2), Some(1), "survivor kept its slot");
        assert_eq!(view.session_pod(3), Some(0));
        // Ending an unknown / stateless session is a no-op.
        view.end_session(42);
        view.end_session(0);
        assert_eq!(view.tracked_sessions(), 2);
        // A re-noted session after end re-sticks fresh.
        view.note_route(1, 1);
        assert_eq!(view.session_pod(1), Some(1));
    }

    #[test]
    fn counter_pod_splits_queues_and_kv_pressure() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(3);
        pods[0].waiting = 4;
        pods[0].running = 2;
        pods[0].kv_pressure = 0.75;
        pods[1].kv_pressure = 0.25;
        pods[2].ready = false; // excluded from the fleet aggregate
        pods[2].kv_pressure = 1.0;
        assert_eq!(pods[0].inflight(), 6);
        let snaps = view.snapshot(0, &req(16, 0), &mut pods, None);
        assert_eq!(snaps[0].stats.waiting, 4);
        assert_eq!(snaps[0].stats.running, 2);
        assert!((snaps[0].stats.kv_utilization - 0.75).abs() < 1e-12);
        // Fleet pressure averages only pods accepting new work.
        assert!((fleet_kv_pressure(&snaps) - 0.5).abs() < 1e-12);
        assert_eq!(fleet_kv_pressure(&[]), 0.0);
    }

    #[test]
    fn from_env_rejects_garbage() {
        // Only exercises the parse paths that need no process-global env
        // mutation: defaults are valid.
        let cfg = ClusterViewConfig::from_env().expect("defaults parse");
        assert!(cfg.session_capacity > 0);
    }
}
