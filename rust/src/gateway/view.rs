//! ClusterView — the unified routing signal plane.
//!
//! Before this layer, every entry point (sim harness, `aibrix serve`, the
//! autoscaler simulation, experiments, benches) hand-rolled its own
//! [`PodSnapshot`]s from whatever subset of signals it happened to have,
//! and `prefix_match_blocks` only ever saw engine-local caches — the
//! distributed KV pool (kvcache/pool.rs) was invisible to placement.
//! `ClusterView` is the single snapshot producer: it composes, per
//! request,
//!
//!   * **raw pod signals** — load/latency/KV stats, readiness, resident
//!     adapters, engine-local prefix matches — via [`PodSignalSource`]
//!     (implemented by the engine simulator, by counter-backed
//!     [`CounterPod`]s for the HTTP server, and by plain [`PodSignals`]
//!     values for tests);
//!   * **pool residency** — [`DistKvPool::residency`] per node, hashed
//!     with the same chain seed the serving path uses, so
//!     `prefix_match_blocks` / `pool_blocks_*` reflect *pool* state per
//!     node and the router can prefer the replica whose shard already
//!     holds the prompt's blocks;
//!   * **SLO targets** — from [`crate::optimizer::profiles::Slo`], turned
//!     into a per-pod latency-budget headroom signal;
//!   * **session stickiness** — a bounded session→pod table maintained by
//!     [`ClusterView::note_route`], so multi-turn chats keep KV locality
//!     even when prefix caches churn.
//!
//! The snapshot is a pure function of (config, pod signals, pool state,
//! session table): same inputs ⇒ identical `PodSnapshot` vector, whatever
//! entry point produced them (property-tested in `tests/cluster_view.rs`).

use std::collections::{HashMap, VecDeque};

use super::router::PodSnapshot;
use crate::engine::prefix::{prompt_block_keys_seeded_into, BlockKey};
use crate::engine::{EngineSim, EngineStats};
use crate::kvcache::DistKvPool;
use crate::optimizer::profiles::Slo;
use crate::sim::SimTime;
use crate::workload::Request;

/// Configuration of the signal plane.
#[derive(Debug, Clone)]
pub struct ClusterViewConfig {
    /// Tokens per content-addressed block — must match the engines' block
    /// size (and the pool's `block_tokens`) or residency probes miss.
    pub block_size: usize,
    /// Chain-hash seed: 0 for the simulator's unseeded chain,
    /// [`crate::engine::prefix::model_chain_seed`]-derived for the real
    /// serving path (ask the `EnginePool` hook via `chain_seed()`).
    pub chain_seed: BlockKey,
    /// SLO targets feeding the slo-headroom signal.
    pub slo: Slo,
    /// Bound on tracked sessions; oldest-by-first-appearance evicts first.
    pub session_capacity: usize,
}

impl Default for ClusterViewConfig {
    fn default() -> ClusterViewConfig {
        ClusterViewConfig {
            block_size: 16,
            chain_seed: 0,
            slo: Slo::default(),
            session_capacity: 4096,
        }
    }
}

impl ClusterViewConfig {
    /// Defaults with the operator env knobs applied:
    /// `AIBRIX_SLO_TTFT_MS`, `AIBRIX_SLO_ITL_MS`, `AIBRIX_SESSION_CAP`.
    /// Garbage values are hard errors, never silent defaults.
    pub fn from_env() -> Result<ClusterViewConfig, String> {
        let mut cfg = ClusterViewConfig::default();
        if let Ok(v) = std::env::var("AIBRIX_SLO_TTFT_MS") {
            cfg.slo.ttft_ms = v
                .parse()
                .map_err(|_| format!("AIBRIX_SLO_TTFT_MS={v:?} is not a number"))?;
        }
        if let Ok(v) = std::env::var("AIBRIX_SLO_ITL_MS") {
            cfg.slo.itl_ms = v
                .parse()
                .map_err(|_| format!("AIBRIX_SLO_ITL_MS={v:?} is not a number"))?;
        }
        if let Ok(v) = std::env::var("AIBRIX_SESSION_CAP") {
            cfg.session_capacity = v
                .parse()
                .map_err(|_| format!("AIBRIX_SESSION_CAP={v:?} is not a number"))?;
        }
        Ok(cfg)
    }
}

/// One pod's raw signals, before pool/session/SLO enrichment.
#[derive(Debug, Clone, PartialEq)]
pub struct PodSignals {
    pub pod: usize,
    /// Hosting node (pool colocation identity).
    pub node: u64,
    pub ready: bool,
    pub stats: EngineStats,
    /// Leading prompt blocks held by the pod's engine-local prefix cache.
    pub local_match_blocks: usize,
    pub resident_adapters: Vec<String>,
}

/// Anything that can report one pod's raw routing signals for a request
/// whose prompt hashes to `keys`.
pub trait PodSignalSource {
    fn signals(&mut self, now: SimTime, keys: &[BlockKey]) -> PodSignals;
}

impl PodSignalSource for EngineSim {
    fn signals(&mut self, now: SimTime, keys: &[BlockKey]) -> PodSignals {
        PodSignals {
            pod: self.id,
            node: self.node,
            ready: !self.is_failed(),
            stats: self.stats(now),
            local_match_blocks: self.prefix_match_blocks(keys),
            resident_adapters: self.resident_adapters().to_vec(),
        }
    }
}

/// Pre-assembled signals pass through unchanged (tests, replays).
impl PodSignalSource for PodSignals {
    fn signals(&mut self, _now: SimTime, _keys: &[BlockKey]) -> PodSignals {
        self.clone()
    }
}

/// Counter-backed pod for entry points without an engine simulator —
/// `aibrix serve` tracks only a live in-flight count per replica; every
/// other raw signal is neutral and the view supplies pool/session/SLO.
#[derive(Debug, Clone)]
pub struct CounterPod {
    pub pod: usize,
    pub node: u64,
    pub ready: bool,
    /// Admitted-but-unfinished requests (the load signal).
    pub inflight: usize,
}

impl PodSignalSource for CounterPod {
    fn signals(&mut self, _now: SimTime, _keys: &[BlockKey]) -> PodSignals {
        PodSignals {
            pod: self.pod,
            node: self.node,
            ready: self.ready,
            stats: EngineStats { waiting: self.inflight, ..EngineStats::default() },
            local_match_blocks: 0,
            resident_adapters: Vec::new(),
        }
    }
}

/// Headroom vs the SLO latency budget in `[0, 1]`: the pod's recent mean
/// end-to-end latency against this request's budget (TTFT target + ITL
/// target × requested output tokens). 1 = far under target, 0 = at/over.
/// A pod with no latency history (fresh cluster) reports full headroom.
pub fn slo_headroom(stats: &EngineStats, req: &Request, slo: &Slo) -> f64 {
    let budget_us = (slo.ttft_ms + slo.itl_ms * req.output_len as f64) * 1e3;
    if !budget_us.is_finite() || budget_us <= 0.0 {
        return 0.0; // degenerate budget: no headroom credit
    }
    let h = (1.0 - stats.avg_latency_us / budget_us).clamp(0.0, 1.0);
    if h.is_finite() {
        h
    } else {
        0.0
    }
}

/// Bounded session → pod table. Eviction is FIFO by *first appearance*:
/// re-routing an existing session updates its pod without re-queueing it,
/// so the table stays O(capacity) and fully deterministic.
#[derive(Debug)]
struct SessionTable {
    map: HashMap<u64, usize>,
    order: VecDeque<u64>,
    capacity: usize,
}

impl SessionTable {
    fn new(capacity: usize) -> SessionTable {
        SessionTable { map: HashMap::new(), order: VecDeque::new(), capacity }
    }

    fn note(&mut self, session: u64, pod: usize) {
        if self.capacity == 0 {
            return;
        }
        use std::collections::hash_map::Entry;
        match self.map.entry(session) {
            Entry::Occupied(mut e) => {
                e.insert(pod);
            }
            Entry::Vacant(v) => {
                v.insert(pod);
                self.order.push_back(session);
            }
        }
        while self.order.len() > self.capacity {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
    }

    fn pod_of(&self, session: u64) -> Option<usize> {
        self.map.get(&session).copied()
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// The unified snapshot producer. One instance per routing loop (harness
/// run, server process, bench): it owns the session table and a key
/// scratch buffer, and turns raw pod signals + pool state into the
/// [`PodSnapshot`] vector the scoring pipeline consumes.
pub struct ClusterView {
    cfg: ClusterViewConfig,
    sessions: SessionTable,
    /// Scratch: the request's block-key chain, reused across requests.
    keys: Vec<BlockKey>,
}

impl ClusterView {
    pub fn new(cfg: ClusterViewConfig) -> ClusterView {
        let sessions = SessionTable::new(cfg.session_capacity);
        ClusterView { cfg, sessions, keys: Vec::new() }
    }

    pub fn config(&self) -> &ClusterViewConfig {
        &self.cfg
    }

    /// Record a routing decision for session stickiness. Call on every
    /// `Decision::Route`. Session 0 means *stateless* repo-wide (the
    /// server's sessionless requests, generators start real ids at 1) and
    /// is never tracked — so stray session-less traffic can never herd
    /// onto one pod through a phantom shared session.
    pub fn note_route(&mut self, session: u64, pod: usize) {
        if session != 0 {
            self.sessions.note(session, pod);
        }
    }

    /// Pod the session last routed to, if still tracked (None for the
    /// stateless session 0).
    pub fn session_pod(&self, session: u64) -> Option<usize> {
        if session == 0 {
            return None;
        }
        self.sessions.pod_of(session)
    }

    /// Sessions currently tracked (observability).
    pub fn tracked_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Build the per-request snapshot vector: one [`PodSnapshot`] per
    /// signal source, in order. `pool` is the distributed KV pool when one
    /// is wired in — its residency probe feeds `pool_blocks_*` and lifts
    /// `prefix_match_blocks` to the max of engine-local and pool-local
    /// state, making the pool a placement signal.
    pub fn snapshot<S: PodSignalSource>(
        &mut self,
        now: SimTime,
        req: &Request,
        pods: &mut [S],
        pool: Option<&DistKvPool>,
    ) -> Vec<PodSnapshot> {
        // Hash the prompt chain once per request into the scratch buffer —
        // the same walk the engines' admission lookups use, by definition.
        let bs = self.cfg.block_size.max(1);
        prompt_block_keys_seeded_into(self.cfg.chain_seed, &req.tokens, bs, &mut self.keys);
        let prompt_blocks = self.keys.len().max(1);
        let sticky = self.session_pod(req.session);

        let mut out = Vec::with_capacity(pods.len());
        for p in pods.iter_mut() {
            let s = p.signals(now, &self.keys);
            let res = match pool {
                Some(pool) => pool.residency(now, s.node, &self.keys),
                None => Default::default(),
            };
            out.push(PodSnapshot {
                pod: s.pod,
                ready: s.ready,
                prefix_match_blocks: s.local_match_blocks.max(res.local_blocks),
                prompt_blocks,
                pool_blocks_local: res.local_blocks,
                pool_blocks_total: res.visible_blocks,
                session_match: sticky == Some(s.pod),
                slo_headroom: slo_headroom(&s.stats, req, &self.cfg.slo),
                resident_adapters: s.resident_adapters,
                stats: s.stats,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvPoolConfig;

    fn req(tokens: usize, session: u64) -> Request {
        Request {
            id: 0,
            session,
            tokens: (0..tokens as u32).collect(),
            output_len: 8,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
        }
    }

    fn counter_pods(n: usize) -> Vec<CounterPod> {
        (0..n)
            .map(|i| CounterPod { pod: i, node: i as u64, ready: true, inflight: i })
            .collect()
    }

    #[test]
    fn snapshot_is_total_and_ordered() {
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let mut pods = counter_pods(3);
        let snaps = view.snapshot(0, &req(64, 0), &mut pods, None);
        assert_eq!(snaps.len(), 3);
        for (i, s) in snaps.iter().enumerate() {
            assert_eq!(s.pod, i);
            assert_eq!(s.stats.waiting, i);
            assert_eq!(s.prompt_blocks, 4);
            assert_eq!(s.pool_blocks_total, 0);
            assert!(!s.session_match);
        }
    }

    #[test]
    fn session_table_sticks_and_bounds() {
        let cfg = ClusterViewConfig { session_capacity: 2, ..Default::default() };
        let mut view = ClusterView::new(cfg);
        view.note_route(1, 0);
        view.note_route(2, 1);
        let mut pods = counter_pods(2);
        let snaps = view.snapshot(0, &req(16, 2), &mut pods, None);
        assert!(!snaps[0].session_match);
        assert!(snaps[1].session_match);
        // Re-noting an existing session updates in place (no eviction).
        view.note_route(1, 1);
        assert_eq!(view.session_pod(1), Some(1));
        assert_eq!(view.tracked_sessions(), 2);
        // A third session evicts the oldest (session 1: first appearance).
        view.note_route(3, 0);
        assert_eq!(view.tracked_sessions(), 2);
        assert_eq!(view.session_pod(1), None, "oldest session evicted");
        assert_eq!(view.session_pod(2), Some(1));
        assert_eq!(view.session_pod(3), Some(0));
    }

    #[test]
    fn pool_residency_feeds_prefix_and_pool_signals() {
        use crate::engine::ExternalKv;
        let mut pool = DistKvPool::new(KvPoolConfig::new(
            vec![(0, 1 << 30), (1, 1 << 30)],
            1024,
            16,
        ));
        let mut view = ClusterView::new(ClusterViewConfig::default());
        let r = req(64, 0); // 4 full blocks
        // Insert the prompt's first 3 block keys as node 0 (the view and
        // the pool must agree on the chain).
        let keys = crate::engine::prefix::prompt_block_keys(&r.tokens, 16);
        pool.insert(0, 0, &keys[..3], 16);
        let mut pods = counter_pods(2);
        // Past the visibility delay: both pods see 3 blocks, only pod 0
        // owns them.
        let snaps = view.snapshot(100_000, &r, &mut pods, Some(&pool));
        assert_eq!(snaps[0].pool_blocks_local, 3);
        assert_eq!(snaps[0].pool_blocks_total, 3);
        assert_eq!(snaps[0].prefix_match_blocks, 3, "pool feeds the prefix signal");
        assert_eq!(snaps[1].pool_blocks_local, 0);
        assert_eq!(snaps[1].pool_blocks_total, 3);
        assert_eq!(snaps[1].prefix_match_blocks, 0);
        assert!(snaps[0].pool_hit_fraction() > snaps[1].pool_hit_fraction());
    }

    #[test]
    fn slo_headroom_scales_with_latency_and_budget() {
        let slo = Slo { ttft_ms: 1_000.0, itl_ms: 100.0 };
        let r = req(16, 0); // output_len 8 -> budget 1.8s
        let mut stats = EngineStats::default();
        assert_eq!(slo_headroom(&stats, &r, &slo), 1.0, "no history = full headroom");
        stats.avg_latency_us = 900_000.0; // half the budget
        assert!((slo_headroom(&stats, &r, &slo) - 0.5).abs() < 1e-9);
        stats.avg_latency_us = 5_000_000.0; // far over
        assert_eq!(slo_headroom(&stats, &r, &slo), 0.0);
    }

    #[test]
    fn from_env_rejects_garbage() {
        // Only exercises the parse paths that need no process-global env
        // mutation: defaults are valid.
        let cfg = ClusterViewConfig::from_env().expect("defaults parse");
        assert!(cfg.session_capacity > 0);
    }
}
