//! Token-bucket rate limiting: RPM and TPM per tenant (§3.1 data plane:
//! "enforcing fairness policies, rate control (TPM/RPM)").
//!
//! LLM rate control is token-based, not just request-based — the paper
//! calls out circuit-breaker/QPS limits as a microservice-ism that does not
//! fit; TPM is the native unit here.

use crate::sim::{SimTime, SECONDS};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
pub struct RateLimitConfig {
    /// Requests per minute per tenant.
    pub rpm: u64,
    /// Tokens (prompt + max output) per minute per tenant.
    pub tpm: u64,
}

#[derive(Debug, Clone)]
struct Bucket {
    requests: f64,
    tokens: f64,
    refilled_at: SimTime,
}

/// Per-tenant dual token bucket.
#[derive(Debug)]
pub struct RateLimiter {
    cfg: RateLimitConfig,
    buckets: HashMap<u32, Bucket>,
}

impl RateLimiter {
    pub fn new(cfg: RateLimitConfig) -> RateLimiter {
        assert!(cfg.rpm > 0 && cfg.tpm > 0);
        RateLimiter { cfg, buckets: HashMap::new() }
    }

    /// Try to admit a request of `tokens` total tokens for `user` at `now`.
    /// Err(retry_after_ms) when over limit.
    pub fn check(&mut self, now: SimTime, user: u32, tokens: u64) -> Result<(), u64> {
        let cfg = self.cfg;
        let b = self.buckets.entry(user).or_insert(Bucket {
            requests: cfg.rpm as f64,
            tokens: cfg.tpm as f64,
            refilled_at: now,
        });
        // Continuous refill.
        let dt_min = (now.saturating_sub(b.refilled_at)) as f64 / (60.0 * SECONDS as f64);
        b.requests = (b.requests + dt_min * cfg.rpm as f64).min(cfg.rpm as f64);
        b.tokens = (b.tokens + dt_min * cfg.tpm as f64).min(cfg.tpm as f64);
        b.refilled_at = now;

        if b.requests < 1.0 {
            let wait_min = (1.0 - b.requests) / cfg.rpm as f64;
            return Err((wait_min * 60_000.0).ceil() as u64);
        }
        if b.tokens < tokens as f64 {
            let wait_min = (tokens as f64 - b.tokens) / cfg.tpm as f64;
            return Err((wait_min * 60_000.0).ceil() as u64);
        }
        b.requests -= 1.0;
        b.tokens -= tokens as f64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpm_enforced() {
        let mut rl = RateLimiter::new(RateLimitConfig { rpm: 3, tpm: 1_000_000 });
        for _ in 0..3 {
            assert!(rl.check(0, 1, 10).is_ok());
        }
        let err = rl.check(0, 1, 10).unwrap_err();
        assert!(err > 0, "retry-after must be positive");
    }

    #[test]
    fn tpm_enforced_independently() {
        let mut rl = RateLimiter::new(RateLimitConfig { rpm: 1_000, tpm: 100 });
        assert!(rl.check(0, 1, 80).is_ok());
        let err = rl.check(0, 1, 80).unwrap_err();
        // Needs 60 more tokens at 100/min -> ~36s.
        assert!((30_000..48_000).contains(&err), "{err}");
    }

    #[test]
    fn refill_over_time() {
        let mut rl = RateLimiter::new(RateLimitConfig { rpm: 60, tpm: 6_000 });
        // Drain.
        for _ in 0..60 {
            assert!(rl.check(0, 1, 100).is_ok());
        }
        assert!(rl.check(0, 1, 100).is_err());
        // One second refills one request and 100 tokens.
        assert!(rl.check(SECONDS, 1, 100).is_ok());
        assert!(rl.check(SECONDS, 1, 100).is_err());
    }

    #[test]
    fn tenants_isolated() {
        let mut rl = RateLimiter::new(RateLimitConfig { rpm: 1, tpm: 1_000 });
        assert!(rl.check(0, 1, 10).is_ok());
        assert!(rl.check(0, 1, 10).is_err());
        assert!(rl.check(0, 2, 10).is_ok(), "tenant 2 has its own bucket");
    }

    #[test]
    fn bucket_never_exceeds_capacity() {
        let mut rl = RateLimiter::new(RateLimitConfig { rpm: 2, tpm: 1_000 });
        assert!(rl.check(0, 1, 10).is_ok());
        // A long quiet period must not accumulate more than the cap.
        let later = 3_600 * SECONDS;
        assert!(rl.check(later, 1, 10).is_ok());
        assert!(rl.check(later, 1, 10).is_ok());
        assert!(rl.check(later, 1, 10).is_err(), "cap is 2 rpm");
    }
}
