//! Per-tenant fair dispatch queue (workload isolation, §3.1).
//!
//! When every pod is saturated the gateway queues requests; dispatch order
//! uses deficit round-robin weighted by *tokens*, so one tenant flooding
//! long prompts cannot starve others — the LLM analogue of fair queuing
//! (cf. VTC in the serving-fairness literature).

use crate::workload::Request;
use std::collections::{HashMap, VecDeque};

/// Token-weighted deficit round-robin queue.
#[derive(Debug, Default)]
pub struct FairQueue {
    queues: HashMap<u32, VecDeque<Request>>,
    /// Round-robin order of active tenants.
    active: VecDeque<u32>,
    deficits: HashMap<u32, f64>,
    /// Tokens granted per tenant per round.
    pub quantum: f64,
    len: usize,
}

impl FairQueue {
    pub fn new(quantum: f64) -> FairQueue {
        FairQueue { quantum, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, req: Request) {
        let user = req.user;
        let q = self.queues.entry(user).or_default();
        if q.is_empty() && !self.active.contains(&user) {
            self.active.push_back(user);
        }
        q.push_back(req);
        self.len += 1;
    }

    /// Next request under DRR. A tenant at the front serves while its
    /// deficit covers the head request; otherwise it earns one quantum and
    /// rotates to the back, so tenants with cheap requests interleave ahead
    /// of a tenant spending a huge one.
    pub fn pop(&mut self) -> Option<Request> {
        if self.len == 0 {
            return None;
        }
        let mut visits = 0usize;
        let max_visits = 4 * self.active.len() + 4;
        loop {
            let user = *self.active.front()?;
            let q = self.queues.get_mut(&user).unwrap();
            let Some(head) = q.front() else {
                self.active.pop_front();
                self.deficits.remove(&user);
                continue;
            };
            let cost = head.total_tokens() as f64;
            let deficit = self.deficits.entry(user).or_insert(0.0);
            if *deficit >= cost || visits > max_visits {
                *deficit = (*deficit - cost).max(0.0);
                let req = q.pop_front().unwrap();
                self.len -= 1;
                if q.is_empty() {
                    self.active.pop_front();
                    self.deficits.remove(&user);
                }
                return Some(req);
            }
            // Earn one quantum for this visit and yield the turn.
            *deficit += self.quantum;
            self.active.rotate_left(1);
            visits += 1;
        }
    }

    /// Queue depth per tenant (observability).
    pub fn depth_of(&self, user: u32) -> usize {
        self.queues.get(&user).map(|q| q.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, user: u32, tokens: usize) -> Request {
        Request {
            id,
            session: 0,
            tokens: vec![0; tokens],
            output_len: 0,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user,
            shared_prefix_len: 0,
        }
    }

    #[test]
    fn fifo_within_tenant() {
        let mut q = FairQueue::new(1000.0);
        q.push(req(1, 0, 10));
        q.push(req(2, 0, 10));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn interleaves_tenants() {
        let mut q = FairQueue::new(100.0);
        for i in 0..3 {
            q.push(req(i, 0, 100));
            q.push(req(10 + i, 1, 100));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.user).collect();
        // Both tenants appear in the first half.
        assert!(order[..3].contains(&0) && order[..3].contains(&1), "{order:?}");
        assert_eq!(order.len(), 6);
    }

    #[test]
    fn token_weighting_throttles_heavy_tenant() {
        let mut q = FairQueue::new(100.0);
        // Tenant 0: huge requests; tenant 1: small ones.
        for i in 0..3 {
            q.push(req(i, 0, 1000));
        }
        for i in 0..6 {
            q.push(req(100 + i, 1, 100));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.user).collect();
        // Tenant 1 should get several requests through before tenant 0's
        // second giant request.
        let second_heavy = order
            .iter()
            .enumerate()
            .filter(|(_, &u)| u == 0)
            .nth(1)
            .map(|(i, _)| i)
            .unwrap();
        let light_before = order[..second_heavy].iter().filter(|&&u| u == 1).count();
        assert!(light_before >= 3, "{order:?}");
    }

    #[test]
    fn no_livelock_on_oversized_request() {
        let mut q = FairQueue::new(1.0); // tiny quantum
        q.push(req(1, 0, 100_000));
        assert_eq!(q.pop().unwrap().id, 1, "must not livelock");
    }

    #[test]
    fn len_tracks() {
        let mut q = FairQueue::new(10.0);
        assert!(q.is_empty());
        q.push(req(1, 0, 5));
        q.push(req(2, 1, 5));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.depth_of(0) + q.depth_of(1), 1);
    }
}
