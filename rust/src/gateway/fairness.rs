//! Per-tenant fairness (workload isolation, §3.1).
//!
//! Two cooperating pieces:
//!   * [`FairQueue`] — when every pod is saturated the gateway queues
//!     requests; dispatch order uses deficit round-robin weighted by
//!     *tokens*, so one tenant flooding long prompts cannot starve others —
//!     the LLM analogue of fair queuing (cf. VTC in the serving-fairness
//!     literature).
//!   * [`TenantUsage`] — a decayed per-tenant token meter whose
//!     [`TenantUsage::share`] feeds the routing pipeline's fairness scorer
//!     ([`super::scoring::ScoreCtx`]): heavy tenants consolidate onto busy
//!     pods, keeping idle capacity responsive for light tenants.

use crate::sim::SimTime;
use crate::workload::Request;
use std::collections::{HashMap, VecDeque};

/// Token-weighted deficit round-robin queue.
#[derive(Debug, Default)]
pub struct FairQueue {
    queues: HashMap<u32, VecDeque<Request>>,
    /// Round-robin order of active tenants.
    active: VecDeque<u32>,
    deficits: HashMap<u32, f64>,
    /// Tokens granted per tenant per round.
    pub quantum: f64,
    len: usize,
}

impl FairQueue {
    pub fn new(quantum: f64) -> FairQueue {
        FairQueue { quantum, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, req: Request) {
        let user = req.user;
        let q = self.queues.entry(user).or_default();
        if q.is_empty() && !self.active.contains(&user) {
            self.active.push_back(user);
        }
        q.push_back(req);
        self.len += 1;
    }

    /// Next request under DRR. A tenant at the front serves while its
    /// deficit covers the head request; otherwise it earns one quantum and
    /// rotates to the back, so tenants with cheap requests interleave ahead
    /// of a tenant spending a huge one.
    pub fn pop(&mut self) -> Option<Request> {
        if self.len == 0 {
            return None;
        }
        let mut visits = 0usize;
        let max_visits = 4 * self.active.len() + 4;
        loop {
            let user = *self.active.front()?;
            // A rotation entry without a backing queue is an invariant
            // slip; shed the stale tenant and keep dispatching rather than
            // panicking the gateway's queue drain.
            let Some(q) = self.queues.get_mut(&user) else {
                self.active.pop_front();
                self.deficits.remove(&user);
                continue;
            };
            let Some(head) = q.front() else {
                self.active.pop_front();
                self.deficits.remove(&user);
                continue;
            };
            let cost = head.total_tokens() as f64;
            let deficit = self.deficits.entry(user).or_insert(0.0);
            if *deficit >= cost || visits > max_visits {
                *deficit = (*deficit - cost).max(0.0);
                match q.pop_front() {
                    Some(req) => {
                        self.len -= 1;
                        if q.is_empty() {
                            self.active.pop_front();
                            self.deficits.remove(&user);
                        }
                        return Some(req);
                    }
                    // front() succeeded just above, so this arm never runs;
                    // treat it as an emptied tenant instead of panicking.
                    None => {
                        self.active.pop_front();
                        self.deficits.remove(&user);
                        continue;
                    }
                }
            }
            // Earn one quantum for this visit and yield the turn.
            *deficit += self.quantum;
            self.active.rotate_left(1);
            visits += 1;
        }
    }

    /// Queue depth per tenant (observability).
    pub fn depth_of(&self, user: u32) -> usize {
        self.queues.get(&user).map(|q| q.len()).unwrap_or(0)
    }
}

/// Exponentially-decayed per-tenant token usage: the fairness signal the
/// gateway hands the routing pipeline. Everything decays with the same
/// half-life, so `share` is a stable fraction of *recent* traffic.
#[derive(Debug)]
pub struct TenantUsage {
    /// Half-life of the decay, µs of sim/wall time.
    pub halflife_us: f64,
    /// user -> (last update time, decayed token count).
    tenants: HashMap<u32, (SimTime, f64)>,
    /// (last update time, decayed total token count).
    global: (SimTime, f64),
}

impl TenantUsage {
    pub fn new(halflife_us: f64) -> TenantUsage {
        TenantUsage { halflife_us, tenants: HashMap::new(), global: (0, 0.0) }
    }

    fn decayed(&self, value: f64, last: SimTime, now: SimTime) -> f64 {
        if now <= last || value == 0.0 {
            return value;
        }
        value * 0.5f64.powf((now - last) as f64 / self.halflife_us)
    }

    /// Charge `tokens` to `user` at time `now`.
    pub fn record(&mut self, now: SimTime, user: u32, tokens: u64) {
        let (last, value) = self.tenants.get(&user).copied().unwrap_or((now, 0.0));
        let decayed = self.decayed(value, last, now);
        self.tenants.insert(user, (now, decayed + tokens as f64));
        let g = self.decayed(self.global.1, self.global.0, now);
        self.global = (now, g + tokens as f64);
        // Bound memory under high tenant cardinality: entries that have
        // decayed to dust carry no share signal and can be dropped.
        if self.tenants.len() > 1024 {
            let halflife = self.halflife_us;
            self.tenants.retain(|_, &mut (last, value)| {
                let dt = now.saturating_sub(last) as f64;
                value * 0.5f64.powf(dt / halflife) >= 0.5
            });
        }
    }

    /// `user`'s fraction of recent token usage, in `[0, 1]`; 0.0 when the
    /// meter is empty (no traffic yet).
    pub fn share(&self, now: SimTime, user: u32) -> f64 {
        let total = self.decayed(self.global.1, self.global.0, now);
        if total <= 0.0 {
            return 0.0;
        }
        let (last, value) = self.tenants.get(&user).copied().unwrap_or((now, 0.0));
        (self.decayed(value, last, now) / total).clamp(0.0, 1.0)
    }
}

impl Default for TenantUsage {
    /// 60s half-life: long enough to see sustained hogging, short enough
    /// to forgive bursts.
    fn default() -> TenantUsage {
        TenantUsage::new(60_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, user: u32, tokens: usize) -> Request {
        Request {
            id,
            session: 0,
            tokens: vec![0; tokens],
            output_len: 0,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: Default::default(),
        }
    }

    #[test]
    fn fifo_within_tenant() {
        let mut q = FairQueue::new(1000.0);
        q.push(req(1, 0, 10));
        q.push(req(2, 0, 10));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn interleaves_tenants() {
        let mut q = FairQueue::new(100.0);
        for i in 0..3 {
            q.push(req(i, 0, 100));
            q.push(req(10 + i, 1, 100));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.user).collect();
        // Both tenants appear in the first half.
        assert!(order[..3].contains(&0) && order[..3].contains(&1), "{order:?}");
        assert_eq!(order.len(), 6);
    }

    #[test]
    fn token_weighting_throttles_heavy_tenant() {
        let mut q = FairQueue::new(100.0);
        // Tenant 0: huge requests; tenant 1: small ones.
        for i in 0..3 {
            q.push(req(i, 0, 1000));
        }
        for i in 0..6 {
            q.push(req(100 + i, 1, 100));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|r| r.user).collect();
        // Tenant 1 should get several requests through before tenant 0's
        // second giant request.
        let second_heavy = order
            .iter()
            .enumerate()
            .filter(|(_, &u)| u == 0)
            .nth(1)
            .map(|(i, _)| i)
            .unwrap();
        let light_before = order[..second_heavy].iter().filter(|&&u| u == 1).count();
        assert!(light_before >= 3, "{order:?}");
    }

    #[test]
    fn no_livelock_on_oversized_request() {
        let mut q = FairQueue::new(1.0); // tiny quantum
        q.push(req(1, 0, 100_000));
        assert_eq!(q.pop().unwrap().id, 1, "must not livelock");
    }

    #[test]
    fn tenant_usage_share_tracks_and_decays() {
        let mut u = TenantUsage::new(1_000_000.0); // 1s half-life
        assert_eq!(u.share(0, 7), 0.0, "empty meter");
        u.record(0, 7, 3000);
        u.record(0, 8, 1000);
        assert!((u.share(0, 7) - 0.75).abs() < 1e-9);
        assert!((u.share(0, 8) - 0.25).abs() < 1e-9);
        // Uniform decay leaves shares unchanged...
        assert!((u.share(2_000_000, 7) - 0.75).abs() < 1e-9);
        // ...but fresh traffic from the other tenant shifts them.
        u.record(2_000_000, 8, 3000);
        assert!(u.share(2_000_000, 8) > u.share(2_000_000, 7));
        // Unknown tenants are 0.
        assert_eq!(u.share(2_000_000, 99), 0.0);
    }

    #[test]
    fn len_tracks() {
        let mut q = FairQueue::new(10.0);
        assert!(q.is_empty());
        q.push(req(1, 0, 5));
        q.push(req(2, 1, 5));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.depth_of(0) + q.depth_of(1), 1);
    }
}
