//! Routing policies (§3.2.2) over the composable scoring pipeline.
//!
//! "For each pending request, the current version of AIBrix determines the
//! target instance based on one of the following routing policies: random,
//! throughput, least-request, least-kv-cache, least-latency,
//! prefix-cache-aware." Each of those is a *preset* of
//! [`super::scoring::ScoringPipeline`] (a single scorer at weight 1.0);
//! [`Policy::Weighted`] exposes arbitrary weight mixes. Decisions run over
//! [`PodSnapshot`]s — cheap point-in-time views the harness/server
//! refreshes per request — and the decision path is allocation-free
//! (§Perf target: <5µs per decision, asserted by `benches/microbench.rs`).

use super::scoring::{PipelineConfig, ScoreCtx, ScoringPipeline};
use super::view::HealthState;
use crate::engine::EngineStats;
use crate::util::Rng;
use crate::workload::Request;

/// Credit a pool block homed on *another* node earns relative to a
/// colocated one in the pool-affinity score: a remote hit still skips
/// prefill compute, but pays the network transfer, so it must never
/// outrank the shard that already holds the bytes.
pub const REMOTE_POOL_CREDIT: f64 = 0.25;

/// Credit a pool block resident only in the *cold tier* earns in the
/// pool-affinity score: it still skips prefill compute, but pays a
/// promotion (disk read + RAM insert) before it can seed, so it ranks
/// below both colocated RAM (1.0) and remote RAM ([`REMOTE_POOL_CREDIT`]).
pub const COLD_POOL_CREDIT: f64 = 0.10;

/// Point-in-time view of one serving pod, as the gateway sees it.
/// Produced by [`super::view::ClusterView`] — every entry point (harness,
/// `aibrix serve`, autoscaler sim, benches) routes from the same snapshot
/// shape instead of hand-rolling field subsets.
#[derive(Debug, Clone, PartialEq)]
pub struct PodSnapshot {
    /// Engine/pod index used by the harness.
    pub pod: usize,
    pub ready: bool,
    /// Health-machine verdict ([`super::view::HealthState`]): Draining
    /// pods take no *new* work, Cordoned pods are excluded outright (the
    /// view also forces `ready = false` for them).
    pub health: HealthState,
    pub stats: EngineStats,
    /// Full prompt blocks of *this request* the pod can serve warm: its
    /// engine-local prefix cache, or — when a distributed pool is wired in
    /// — the blocks homed on the pod's own pool shard (max of the two).
    pub prefix_match_blocks: usize,
    /// Total full blocks of this request's prompt (for the hit fraction).
    pub prompt_blocks: usize,
    /// Leading prompt blocks resident in the distributed KV pool on this
    /// pod's own node (colocated — shared-memory fetch, no network).
    pub pool_blocks_local: usize,
    /// Longest pool prefix visible to this pod at all (local + remote +
    /// cold); remote blocks still skip prefill compute at transfer cost.
    pub pool_blocks_total: usize,
    /// Leading prompt blocks within that prefix resident only in the
    /// pool's cold spill tier (third residency class: promotable, but at
    /// disk-read cost — scored by [`COLD_POOL_CREDIT`]).
    pub pool_blocks_cold: usize,
    /// True when the request's session last routed to this pod
    /// (session-sticky signal; maintained by `ClusterView::note_route`).
    pub session_match: bool,
    /// Headroom vs the SLO latency budget in `[0, 1]`: 1 = far under
    /// target, 0 = at/over. Computed by the view from the pod's recent
    /// mean latency against the request's TTFT+ITL budget.
    pub slo_headroom: f64,
    /// Adapters currently resident (LoRA-aware routing).
    pub resident_adapters: Vec<String>,
}

impl Default for PodSnapshot {
    /// Neutral snapshot for tests/builders: ready, idle, no cache or pool
    /// residency, full SLO headroom.
    fn default() -> PodSnapshot {
        PodSnapshot {
            pod: 0,
            ready: true,
            health: HealthState::Healthy,
            stats: EngineStats::default(),
            prefix_match_blocks: 0,
            prompt_blocks: 0,
            pool_blocks_local: 0,
            pool_blocks_total: 0,
            pool_blocks_cold: 0,
            session_match: false,
            slo_headroom: 1.0,
            resident_adapters: Vec::new(),
        }
    }
}

impl PodSnapshot {
    /// Is this pod eligible for *new* work? Ready, and not
    /// Draining/Cordoned — every selection path (scored or random) gates
    /// on this, so a draining pod finishes its in-flight requests without
    /// ever being handed another.
    pub fn accepts_new_work(&self) -> bool {
        self.ready && self.health.accepts_new_work()
    }

    /// Fraction of the prompt covered by this pod's prefix cache, clamped
    /// to `[0, 1]`: a racing snapshot can report more matched blocks than
    /// the prompt holds (cache refreshed between the two reads), and a
    /// zero-block prompt has no prefix to hit.
    pub fn prefix_hit_fraction(&self) -> f64 {
        if self.prompt_blocks == 0 {
            0.0
        } else {
            (self.prefix_match_blocks as f64 / self.prompt_blocks as f64).min(1.0)
        }
    }

    /// Pool-affinity signal in `[0, 1]`: the fraction of the prompt this
    /// pod can source from the distributed pool, across the three
    /// residency classes — colocated RAM at full credit, remote RAM
    /// discounted by [`REMOTE_POOL_CREDIT`], cold-tier blocks by
    /// [`COLD_POOL_CREDIT`]. Clamped like
    /// [`PodSnapshot::prefix_hit_fraction`] — a racing snapshot can report
    /// more blocks than the prompt holds.
    pub fn pool_hit_fraction(&self) -> f64 {
        if self.prompt_blocks == 0 {
            return 0.0;
        }
        let local = self.pool_blocks_local.min(self.prompt_blocks) as f64;
        let total = self.pool_blocks_total.min(self.prompt_blocks) as f64;
        let cold = (self.pool_blocks_cold.min(self.prompt_blocks) as f64)
            .min((total - local).max(0.0));
        let remote = (total - local - cold).max(0.0);
        ((local + REMOTE_POOL_CREDIT * remote + COLD_POOL_CREDIT * cold)
            / self.prompt_blocks as f64)
            .min(1.0)
    }
}

/// The paper's routing policies, plus arbitrary weighted mixes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Randomly selects an available instance.
    Random,
    /// Lowest recent tokens-per-second.
    Throughput,
    /// Lowest number of admitted (waiting + running) requests.
    LeastRequest,
    /// Lowest average KV cache usage.
    LeastKvCache,
    /// Lowest average request latency (queuing + serving).
    LeastLatency,
    /// Prefer instances whose prefix cache covers at least `threshold` of
    /// the prompt; falls back to least-request below the threshold.
    PrefixCacheAware { threshold: f64 },
    /// ClusterView preset: prefer the replica whose pool shard already
    /// holds the prompt's blocks, blended with prefix affinity and load.
    PoolAware,
    /// ClusterView preset: prefer pods with headroom against the SLO
    /// latency budget, blended with load and latency.
    SloAware,
    /// ClusterView preset: keep a session's turns on the pod that served
    /// it last (KV locality survives prefix-cache churn), spilling by load.
    SessionSticky,
    /// Custom weighted scoring mix (the open pipeline form).
    Weighted(PipelineConfig),
}

/// Default prefix-coverage threshold for `prefix-cache-aware`.
pub const DEFAULT_PREFIX_THRESHOLD: f64 = 0.3;

impl Policy {
    /// Parse a policy string. Accepted forms:
    ///   * the six paper names (`random`, `throughput`, `least-request`,
    ///     `least-kv-cache`, `least-latency`, `prefix-cache-aware`),
    ///   * the ClusterView presets (`pool-aware`, `slo-aware`,
    ///     `session-sticky`),
    ///   * `prefix-cache-aware=<f64 in [0,1]>` for an explicit threshold,
    ///   * `weighted:key=w,key=w,...` with keys `prefix`, `least-request`,
    ///     `least-kv-cache`, `least-latency`, `throughput`, `lora`,
    ///     `fairness`, `pool-affinity`, `slo-headroom`, `session-affinity`,
    ///     `health`, plus `threshold=<f64>`. Each key may appear at most
    ///     once — a repeated key is a parse error, never a silent
    ///     last-wins.
    /// Garbage is an error, never silently defaulted.
    pub fn parse(s: &str) -> Result<Policy, String> {
        match s {
            "random" => return Ok(Policy::Random),
            "throughput" => return Ok(Policy::Throughput),
            "least-request" => return Ok(Policy::LeastRequest),
            "least-kv-cache" => return Ok(Policy::LeastKvCache),
            "least-latency" => return Ok(Policy::LeastLatency),
            "prefix-cache-aware" => {
                return Ok(Policy::PrefixCacheAware { threshold: DEFAULT_PREFIX_THRESHOLD })
            }
            "pool-aware" => return Ok(Policy::PoolAware),
            "slo-aware" => return Ok(Policy::SloAware),
            "session-sticky" => return Ok(Policy::SessionSticky),
            _ => {}
        }
        if let Some(v) = s.strip_prefix("prefix-cache-aware=") {
            let threshold: f64 = v
                .parse()
                .map_err(|_| format!("prefix-cache-aware threshold {v:?} is not a number"))?;
            if !threshold.is_finite() || !(0.0..=1.0).contains(&threshold) {
                return Err(format!("prefix-cache-aware threshold {v} must be in [0, 1]"));
            }
            return Ok(Policy::PrefixCacheAware { threshold });
        }
        if let Some(spec) = s.strip_prefix("weighted:") {
            let mut cfg = PipelineConfig::default();
            // Duplicate keys are rejected: `weighted:prefix=0.2,prefix=0.8`
            // silently taking the last weight would mask an operator typo.
            let mut seen: Vec<String> = Vec::new();
            for part in spec.split(',').filter(|p| !p.is_empty()) {
                let (key, val) = part
                    .split_once('=')
                    .ok_or_else(|| format!("weighted term {part:?} must be key=value"))?;
                let w: f64 = val
                    .parse()
                    .map_err(|_| format!("weighted term {key}={val:?} is not a number"))?;
                if seen.iter().any(|k| k == key) {
                    return Err(format!(
                        "duplicate weighted key {key:?} (each scorer may appear once)"
                    ));
                }
                seen.push(key.to_string());
                match key {
                    "prefix" => cfg.prefix_affinity = w,
                    "least-request" => cfg.least_request = w,
                    "least-kv-cache" => cfg.least_kv_cache = w,
                    "least-latency" => cfg.least_latency = w,
                    "throughput" => cfg.throughput = w,
                    "lora" => cfg.lora_residency = w,
                    "fairness" => cfg.fairness = w,
                    "pool-affinity" => cfg.pool_affinity = w,
                    "slo-headroom" => cfg.slo_headroom = w,
                    "session-affinity" => cfg.session_affinity = w,
                    "health" => cfg.health = w,
                    "threshold" => cfg.prefix_threshold = w,
                    _ => return Err(format!("unknown weighted scorer {key:?}")),
                }
            }
            cfg.validate()?;
            return Ok(Policy::Weighted(cfg));
        }
        Err(format!("unknown routing policy {s:?}"))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Random => "random",
            Policy::Throughput => "throughput",
            Policy::LeastRequest => "least-request",
            Policy::LeastKvCache => "least-kv-cache",
            Policy::LeastLatency => "least-latency",
            Policy::PrefixCacheAware { .. } => "prefix-cache-aware",
            Policy::PoolAware => "pool-aware",
            Policy::SloAware => "slo-aware",
            Policy::SessionSticky => "session-sticky",
            Policy::Weighted(_) => "weighted",
        }
    }

    /// The six paper policies (presets; `Weighted` is the open form).
    pub fn all() -> Vec<Policy> {
        vec![
            Policy::Random,
            Policy::Throughput,
            Policy::LeastRequest,
            Policy::LeastKvCache,
            Policy::LeastLatency,
            Policy::PrefixCacheAware { threshold: DEFAULT_PREFIX_THRESHOLD },
        ]
    }

    /// Every named preset: the six paper policies plus the ClusterView-era
    /// composites (`pool-aware`, `slo-aware`, `session-sticky`).
    pub fn extended() -> Vec<Policy> {
        let mut v = Policy::all();
        v.extend([Policy::PoolAware, Policy::SloAware, Policy::SessionSticky]);
        v
    }

    /// Scoring-pipeline preset for this policy; None for `Random` (which
    /// bypasses scoring entirely).
    pub fn pipeline_config(&self) -> Option<PipelineConfig> {
        let cfg = match *self {
            Policy::Random => return None,
            Policy::Throughput => PipelineConfig::single("throughput", 1.0),
            Policy::LeastRequest => PipelineConfig::single("least-request", 1.0),
            Policy::LeastKvCache => PipelineConfig::single("least-kv-cache", 1.0),
            Policy::LeastLatency => PipelineConfig::single("least-latency", 1.0),
            Policy::PrefixCacheAware { threshold } => {
                let mut c = PipelineConfig::single("prefix", 1.0);
                c.prefix_threshold = threshold;
                c
            }
            // Composite presets: the dominant ClusterView signal carries
            // the decision; the load/latency terms keep hotspots at bay
            // even before the overload guard engages.
            Policy::PoolAware => {
                let mut c = PipelineConfig::single("pool-affinity", 0.55);
                c.prefix_affinity = 0.15;
                c.least_request = 0.30;
                c
            }
            Policy::SloAware => {
                let mut c = PipelineConfig::single("slo-headroom", 0.5);
                c.least_request = 0.3;
                c.least_latency = 0.2;
                c
            }
            Policy::SessionSticky => {
                let mut c = PipelineConfig::single("session-affinity", 0.6);
                c.least_request = 0.4;
                c
            }
            Policy::Weighted(cfg) => cfg,
        };
        Some(cfg)
    }
}

/// Stateless-per-request router (the RNG and scratch are the only state).
pub struct Router {
    policy: Policy,
    rng: Rng,
    /// None only for `Policy::Random`.
    pipeline: Option<ScoringPipeline>,
    /// LoRA affinity pre-filter: prefer pods with the adapter resident
    /// (2x admitted-request tolerance before spilling to a cold pod).
    /// On by default for the paper presets (their legacy behavior), off
    /// for `Policy::Weighted` — a weighted mix states its own intent, and
    /// the `lora_residency` scorer would be unreachable behind the
    /// short-circuit.
    pub lora_affinity: bool,
}

impl Router {
    pub fn new(policy: Policy, seed: u64) -> Router {
        Router {
            policy,
            rng: Rng::new(seed),
            pipeline: policy.pipeline_config().map(ScoringPipeline::new),
            lora_affinity: !matches!(policy, Policy::Weighted(_)),
        }
    }

    /// Router over an explicit weighted pipeline.
    pub fn with_pipeline(cfg: PipelineConfig, seed: u64) -> Router {
        Router::new(Policy::Weighted(cfg), seed)
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// The active scoring pipeline (None for `random`).
    pub fn pipeline(&self) -> Option<&ScoringPipeline> {
        self.pipeline.as_ref()
    }

    /// Per-scorer contribution counters (None for `random`, which never
    /// scores).
    pub fn telemetry(&self) -> Option<&super::scoring::RouteTelemetry> {
        self.pipeline.as_ref().map(|p| p.telemetry())
    }

    /// Pick a pod for `req`; None when no pod is ready.
    pub fn select(&mut self, req: &Request, pods: &[PodSnapshot]) -> Option<usize> {
        self.select_with_ctx(req, pods, &ScoreCtx::default())
    }

    /// `select` with gateway-computed context (fairness share etc).
    pub fn select_with_ctx(
        &mut self,
        req: &Request,
        pods: &[PodSnapshot],
        ctx: &ScoreCtx,
    ) -> Option<usize> {
        // LoRA affinity pre-filter: if the request needs an adapter and some
        // ready pod has it resident, restrict to those unless they are
        // heavily overloaded relative to the cluster.
        if self.lora_affinity {
            if let Some(adapter) = &req.adapter {
                let mut min_load = usize::MAX;
                let mut best_warm: Option<(usize, usize)> = None; // (load, pod)
                for p in pods.iter().filter(|p| p.accepts_new_work()) {
                    let load = p.stats.waiting + p.stats.running;
                    min_load = min_load.min(load);
                    if p.resident_adapters.iter().any(|a| a == adapter) {
                        let keep = match best_warm {
                            Some((bl, _)) => load < bl,
                            None => true,
                        };
                        if keep {
                            best_warm = Some((load, p.pod));
                        }
                    }
                }
                if let Some((load, pod)) = best_warm {
                    if load <= min_load.saturating_mul(2).saturating_add(4) {
                        return Some(pod);
                    }
                }
            }
        }
        match &mut self.pipeline {
            Some(pipeline) => pipeline.select(req, pods, ctx),
            None => {
                // Random over the pods still accepting new work.
                let n = pods.iter().filter(|p| p.accepts_new_work()).count();
                if n == 0 {
                    return None;
                }
                let k = self.rng.below(n as u64) as usize;
                pods.iter().filter(|p| p.accepts_new_work()).nth(k).map(|p| p.pod)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pod: usize) -> PodSnapshot {
        PodSnapshot { pod, prompt_blocks: 10, ..Default::default() }
    }

    fn req() -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![0; 160],
            output_len: 1,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: Default::default(),
        }
    }

    #[test]
    fn random_covers_all_ready_pods() {
        let mut r = Router::new(Policy::Random, 3);
        let pods = vec![snap(0), snap(1), snap(2)];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[r.select(&req(), &pods).unwrap()] = true;
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn skips_not_ready() {
        let mut r = Router::new(Policy::Random, 3);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].ready = false;
        for _ in 0..50 {
            assert_eq!(r.select(&req(), &pods), Some(1));
        }
    }

    #[test]
    fn draining_and_cordoned_get_no_new_work() {
        // Draining: still ready (finishing its queue), never selected.
        // Cordoned: fully excluded. Applies to scored *and* random paths.
        for policy in [Policy::Random, Policy::LeastRequest, Policy::PoolAware] {
            let mut r = Router::new(policy, 7);
            let mut pods = vec![snap(0), snap(1), snap(2)];
            pods[0].health = HealthState::Draining;
            pods[2].health = HealthState::Cordoned;
            for _ in 0..50 {
                assert_eq!(r.select(&req(), &pods), Some(1), "{}", policy.name());
            }
            // With every pod out of rotation the router returns None, so
            // the gateway surfaces NoCapacity instead of feeding a corpse.
            pods[1].health = HealthState::Draining;
            assert_eq!(r.select(&req(), &pods), None, "{}", policy.name());
        }
        // Degraded pods remain eligible (the health scorer just
        // deprioritizes them in weighted mixes).
        let mut r = Router::new(Policy::LeastRequest, 7);
        let mut pods = vec![snap(0)];
        pods[0].health = HealthState::Degraded;
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn lora_prefilter_respects_health() {
        let mut r = Router::new(Policy::LeastRequest, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["lora-x".into()];
        pods[1].health = HealthState::Draining;
        let mut rq = req();
        rq.adapter = Some("lora-x".into());
        assert_eq!(r.select(&rq, &pods), Some(0), "warm-but-draining pod skipped");
    }

    #[test]
    fn least_request_picks_idle() {
        let mut r = Router::new(Policy::LeastRequest, 1);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[0].stats.waiting = 5;
        pods[1].stats.running = 2;
        assert_eq!(r.select(&req(), &pods), Some(2));
    }

    #[test]
    fn least_kv_cache() {
        let mut r = Router::new(Policy::LeastKvCache, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.kv_utilization = 0.9;
        pods[1].stats.kv_utilization = 0.2;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn least_latency() {
        let mut r = Router::new(Policy::LeastLatency, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.avg_latency_us = 50_000.0;
        pods[1].stats.avg_latency_us = 250_000.0;
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn throughput_picks_lowest() {
        let mut r = Router::new(Policy::Throughput, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.tokens_per_s = 4_000.0;
        pods[1].stats.tokens_per_s = 100.0;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_prefers_hit_above_threshold() {
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.3 }, 1);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[1].prefix_match_blocks = 8; // 80% hit
        pods[1].stats.waiting = 3; // moderately loaded: affinity holds
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_overload_guard_breaks_affinity() {
        // A warm pod far above the cluster minimum loses its claim — cache
        // affinity must not create hotspots.
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.3 }, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].prefix_match_blocks = 10; // 100% hit
        pods[1].stats.waiting = 20; // but 20 > 0*2 + 4
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn least_latency_outlier_ejection() {
        // The stale-signal pod (low recorded latency, huge queue) must be
        // ejected in favor of a live one.
        let mut r = Router::new(Policy::LeastLatency, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.avg_latency_us = 1_000.0; // looks fast...
        pods[0].stats.waiting = 30; // ...but drowning
        pods[1].stats.avg_latency_us = 80_000.0;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_falls_back_below_threshold() {
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.5 }, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].prefix_match_blocks = 2; // 20% < 50%
        pods[0].stats.waiting = 9;
        pods[1].stats.waiting = 1;
        assert_eq!(r.select(&req(), &pods), Some(1), "fallback to least-request");
    }

    #[test]
    fn lora_affinity_prefers_warm_pod() {
        let mut r = Router::new(Policy::LeastRequest, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["lora-x".into()];
        pods[1].stats.running = 2; // warm but slightly busier
        let mut rq = req();
        rq.adapter = Some("lora-x".into());
        assert_eq!(r.select(&rq, &pods), Some(1));
        // Unless the warm pod is overloaded.
        pods[1].stats.waiting = 50;
        assert_eq!(r.select(&rq, &pods), Some(0));
    }

    #[test]
    fn deterministic_under_seed() {
        let pods = vec![snap(0), snap(1), snap(2)];
        let picks1: Vec<_> = {
            let mut r = Router::new(Policy::Random, 42);
            (0..20).map(|_| r.select(&req(), &pods).unwrap()).collect()
        };
        let picks2: Vec<_> = {
            let mut r = Router::new(Policy::Random, 42);
            (0..20).map(|_| r.select(&req(), &pods).unwrap()).collect()
        };
        assert_eq!(picks1, picks2);
    }

    #[test]
    fn parse_paper_policies_and_threshold_forms() {
        for name in [
            "random",
            "throughput",
            "least-request",
            "least-kv-cache",
            "least-latency",
            "prefix-cache-aware",
        ] {
            assert_eq!(Policy::parse(name).unwrap().name(), name);
        }
        assert_eq!(
            Policy::parse("prefix-cache-aware").unwrap(),
            Policy::PrefixCacheAware { threshold: DEFAULT_PREFIX_THRESHOLD }
        );
        assert_eq!(
            Policy::parse("prefix-cache-aware=0.75").unwrap(),
            Policy::PrefixCacheAware { threshold: 0.75 }
        );
        // Garbage and out-of-range thresholds are errors, never defaults.
        assert!(Policy::parse("prefix-cache-aware=lots").is_err());
        assert!(Policy::parse("prefix-cache-aware=1.5").is_err());
        assert!(Policy::parse("prefix-cache-aware=-0.1").is_err());
        assert!(Policy::parse("totally-new-policy").is_err());
    }

    #[test]
    fn parse_weighted_mix() {
        let p = Policy::parse("weighted:prefix=0.6,least-request=0.4,threshold=0.5").unwrap();
        let Policy::Weighted(cfg) = p else { panic!("expected weighted") };
        assert_eq!(cfg.prefix_affinity, 0.6);
        assert_eq!(cfg.least_request, 0.4);
        assert_eq!(cfg.prefix_threshold, 0.5);
        assert_eq!(p.name(), "weighted");
        assert!(Policy::parse("weighted:bogus=1").is_err());
        assert!(Policy::parse("weighted:prefix=abc").is_err());
        assert!(Policy::parse("weighted:").is_err(), "no weights at all");
        assert!(Policy::parse("weighted:threshold=0.5").is_err(), "zero weight vector");
    }

    #[test]
    fn weighted_policy_reaches_lora_scorer() {
        // The pre-filter must not shadow an explicit weighted mix: with
        // lora weight dominating, adapter traffic follows the scorer (and
        // composes with load), not the legacy short-circuit.
        let Policy::Weighted(cfg) =
            Policy::parse("weighted:lora=0.8,least-request=0.2").unwrap()
        else {
            unreachable!()
        };
        let mut r = Router::with_pipeline(cfg, 4);
        assert!(!r.lora_affinity, "weighted presets disable the pre-filter");
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["lora-x".into()];
        let mut rq = req();
        rq.adapter = Some("lora-x".into());
        assert_eq!(r.select(&rq, &pods), Some(1));
    }

    #[test]
    fn weighted_router_routes() {
        let cfg = {
            let Policy::Weighted(c) =
                Policy::parse("weighted:prefix=0.5,least-request=0.5").unwrap()
            else {
                unreachable!()
            };
            c
        };
        let mut r = Router::with_pipeline(cfg, 9);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].prefix_match_blocks = 10;
        assert_eq!(r.select(&req(), &pods), Some(1));
        assert_eq!(r.policy().name(), "weighted");
    }

    #[test]
    fn parse_clusterview_presets() {
        for name in ["pool-aware", "slo-aware", "session-sticky"] {
            let p = Policy::parse(name).unwrap();
            assert_eq!(p.name(), name);
            let cfg = p.pipeline_config().expect("presets score");
            assert!(cfg.validate().is_ok(), "{name}");
        }
        assert_eq!(Policy::parse("pool-aware").unwrap(), Policy::PoolAware);
        assert_eq!(Policy::extended().len(), Policy::all().len() + 3);
    }

    #[test]
    fn parse_weighted_rejects_duplicate_keys() {
        // A repeated key must be a loud parse error, not a silent
        // last-weight-wins.
        for bad in [
            "weighted:prefix=0.2,prefix=0.8",
            "weighted:least-request=1,least-request=2",
            "weighted:pool-affinity=0.5,least-request=0.2,pool-affinity=0.5",
            "weighted:prefix=1,threshold=0.3,threshold=0.4",
        ] {
            let err = Policy::parse(bad).unwrap_err();
            assert!(err.contains("duplicate"), "{bad}: {err}");
        }
        // Distinct keys still parse.
        assert!(Policy::parse("weighted:prefix=0.5,pool-affinity=0.5").is_ok());
    }

    #[test]
    fn parse_new_weighted_scorers() {
        let p = Policy::parse(
            "weighted:pool-affinity=0.4,slo-headroom=0.3,session-affinity=0.3",
        )
        .unwrap();
        let Policy::Weighted(cfg) = p else { panic!("expected weighted") };
        assert_eq!(cfg.pool_affinity, 0.4);
        assert_eq!(cfg.slo_headroom, 0.3);
        assert_eq!(cfg.session_affinity, 0.3);
    }

    #[test]
    fn pool_aware_prefers_shard_owner() {
        let mut r = Router::new(Policy::PoolAware, 1);
        let mut pods = vec![snap(0), snap(1)];
        // Pod 1's shard holds 8 of 10 blocks; pod 0 could only fetch them
        // remotely.
        pods[1].pool_blocks_local = 8;
        pods[1].pool_blocks_total = 8;
        pods[0].pool_blocks_total = 8;
        assert_eq!(r.select(&req(), &pods), Some(1));
        // Overloaded shard owners lose the claim (no pool hotspots).
        pods[1].stats.waiting = 30;
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn session_sticky_follows_prior_route() {
        let mut r = Router::new(Policy::SessionSticky, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].session_match = true;
        pods[1].stats.running = 2; // slightly busier, still sticky
        assert_eq!(r.select(&req(), &pods), Some(1));
        pods[1].stats.waiting = 40; // overloaded: stickiness breaks
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn slo_aware_prefers_headroom() {
        let mut r = Router::new(Policy::SloAware, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].slo_headroom = 0.1; // near its deadline budget
        pods[1].slo_headroom = 0.9;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn pool_hit_fraction_discounts_remote() {
        let mut p = snap(0);
        p.prompt_blocks = 10;
        p.pool_blocks_local = 4;
        p.pool_blocks_total = 8;
        let expect = (4.0 + REMOTE_POOL_CREDIT * 4.0) / 10.0;
        assert!((p.pool_hit_fraction() - expect).abs() < 1e-12);
        // All-local beats the same count split with remote.
        let mut q = snap(0);
        q.prompt_blocks = 10;
        q.pool_blocks_local = 8;
        q.pool_blocks_total = 8;
        assert!(q.pool_hit_fraction() > p.pool_hit_fraction());
        // Racing snapshots clamp; zero-block prompts score 0.
        q.pool_blocks_local = usize::MAX;
        q.pool_blocks_total = usize::MAX;
        assert_eq!(q.pool_hit_fraction(), 1.0);
        q.prompt_blocks = 0;
        assert_eq!(q.pool_hit_fraction(), 0.0);
    }

    #[test]
    fn pool_hit_fraction_ranks_three_residency_classes() {
        // Same 8-block coverage, three residency classes: local RAM must
        // outrank remote RAM, which must outrank cold — and cold must
        // still beat nothing at all.
        let mk = |local, cold| {
            let mut p = snap(0);
            p.prompt_blocks = 10;
            p.pool_blocks_local = local;
            p.pool_blocks_total = 8;
            p.pool_blocks_cold = cold;
            p
        };
        let all_local = mk(8, 0);
        let all_remote = mk(0, 0);
        let all_cold = mk(0, 8);
        assert!(all_local.pool_hit_fraction() > all_remote.pool_hit_fraction());
        assert!(all_remote.pool_hit_fraction() > all_cold.pool_hit_fraction());
        assert!(all_cold.pool_hit_fraction() > 0.0);
        let expect = COLD_POOL_CREDIT * 8.0 / 10.0;
        assert!((all_cold.pool_hit_fraction() - expect).abs() < 1e-12);
        // Mixed: 4 local + 2 remote + 2 cold.
        let mixed = mk(4, 2);
        let expect = (4.0 + REMOTE_POOL_CREDIT * 2.0 + COLD_POOL_CREDIT * 2.0) / 10.0;
        assert!((mixed.pool_hit_fraction() - expect).abs() < 1e-12);
        // A racing cold count exceeding the non-local coverage clamps to
        // it (never double-counts local blocks as cold).
        let over = mk(8, usize::MAX);
        assert_eq!(over.pool_hit_fraction(), 0.8);
    }

    #[test]
    fn prefix_hit_fraction_edge_cases() {
        let mut p = snap(0);
        // Zero-prompt: no prefix to hit.
        p.prompt_blocks = 0;
        p.prefix_match_blocks = 5;
        assert_eq!(p.prefix_hit_fraction(), 0.0);
        // Racing snapshot reporting more matches than prompt blocks clamps.
        p.prompt_blocks = 4;
        p.prefix_match_blocks = 9;
        assert_eq!(p.prefix_hit_fraction(), 1.0);
        // Normal case unaffected.
        p.prompt_blocks = 10;
        p.prefix_match_blocks = 5;
        assert_eq!(p.prefix_hit_fraction(), 0.5);
        // Large values stay finite and clamped.
        p.prompt_blocks = 1;
        p.prefix_match_blocks = usize::MAX;
        assert_eq!(p.prefix_hit_fraction(), 1.0);
    }
}
