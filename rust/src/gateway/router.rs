//! Routing policies (§3.2.2).
//!
//! "For each pending request, the current version of AIBrix determines the
//! target instance based on one of the following routing policies: random,
//! throughput, least-request, least-kv-cache, least-latency,
//! prefix-cache-aware." Each policy scores [`PodSnapshot`]s — cheap
//! point-in-time views the harness/server refreshes per request — and the
//! decision path is allocation-free (§Perf target: <5µs per decision).

use crate::engine::EngineStats;
use crate::util::Rng;
use crate::workload::Request;

/// Point-in-time view of one serving pod, as the gateway sees it.
#[derive(Debug, Clone)]
pub struct PodSnapshot {
    /// Engine/pod index used by the harness.
    pub pod: usize,
    pub ready: bool,
    pub stats: EngineStats,
    /// Full prompt blocks of *this request* matched by the pod's local
    /// prefix cache (the prefix-aware signal).
    pub prefix_match_blocks: usize,
    /// Total full blocks of this request's prompt (for the hit fraction).
    pub prompt_blocks: usize,
    /// Adapters currently resident (LoRA-aware routing).
    pub resident_adapters: Vec<String>,
}

impl PodSnapshot {
    pub fn prefix_hit_fraction(&self) -> f64 {
        if self.prompt_blocks == 0 {
            0.0
        } else {
            self.prefix_match_blocks as f64 / self.prompt_blocks as f64
        }
    }
}

/// The paper's routing policies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Randomly selects an available instance.
    Random,
    /// Lowest recent tokens-per-second.
    Throughput,
    /// Lowest number of admitted (waiting + running) requests.
    LeastRequest,
    /// Lowest average KV cache usage.
    LeastKvCache,
    /// Lowest average request latency (queuing + serving).
    LeastLatency,
    /// Prefer instances whose prefix cache covers at least `threshold` of
    /// the prompt; falls back to least-request below the threshold.
    PrefixCacheAware { threshold: f64 },
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "random" => Some(Policy::Random),
            "throughput" => Some(Policy::Throughput),
            "least-request" => Some(Policy::LeastRequest),
            "least-kv-cache" => Some(Policy::LeastKvCache),
            "least-latency" => Some(Policy::LeastLatency),
            "prefix-cache-aware" => Some(Policy::PrefixCacheAware { threshold: 0.3 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Random => "random",
            Policy::Throughput => "throughput",
            Policy::LeastRequest => "least-request",
            Policy::LeastKvCache => "least-kv-cache",
            Policy::LeastLatency => "least-latency",
            Policy::PrefixCacheAware { .. } => "prefix-cache-aware",
        }
    }

    pub fn all() -> Vec<Policy> {
        vec![
            Policy::Random,
            Policy::Throughput,
            Policy::LeastRequest,
            Policy::LeastKvCache,
            Policy::LeastLatency,
            Policy::PrefixCacheAware { threshold: 0.3 },
        ]
    }
}

/// Stateless-per-request router (the RNG is the only state).
pub struct Router {
    policy: Policy,
    rng: Rng,
    /// LoRA affinity: prefer pods with the adapter resident (2x admitted-
    /// request tolerance before spilling to a cold pod).
    pub lora_affinity: bool,
}

impl Router {
    pub fn new(policy: Policy, seed: u64) -> Router {
        Router { policy, rng: Rng::new(seed), lora_affinity: true }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Pick a pod for `req`; None when no pod is ready.
    pub fn select(&mut self, req: &Request, pods: &[PodSnapshot]) -> Option<usize> {
        // LoRA affinity pre-filter: if the request needs an adapter and some
        // ready pod has it resident, restrict to those unless they are
        // heavily overloaded relative to the cluster.
        if self.lora_affinity {
            if let Some(adapter) = &req.adapter {
                let warm: Vec<&PodSnapshot> = pods
                    .iter()
                    .filter(|p| {
                        p.ready && p.resident_adapters.iter().any(|a| a == adapter)
                    })
                    .collect();
                if !warm.is_empty() {
                    let min_load = pods
                        .iter()
                        .filter(|p| p.ready)
                        .map(|p| p.stats.waiting + p.stats.running)
                        .min()
                        .unwrap_or(0);
                    let best_warm = warm
                        .iter()
                        .min_by_key(|p| p.stats.waiting + p.stats.running)
                        .unwrap();
                    if best_warm.stats.waiting + best_warm.stats.running
                        <= min_load * 2 + 4
                    {
                        return Some(best_warm.pod);
                    }
                }
            }
        }
        self.select_by_policy(req, pods)
    }

    fn select_by_policy(&mut self, _req: &Request, pods: &[PodSnapshot]) -> Option<usize> {
        let ready = || pods.iter().filter(|p| p.ready);
        if ready().next().is_none() {
            return None;
        }
        let pick_min = |key: &dyn Fn(&PodSnapshot) -> f64| -> usize {
            let mut best = usize::MAX;
            let mut best_score = f64::INFINITY;
            for p in pods.iter().filter(|p| p.ready) {
                let s = key(p);
                if s < best_score {
                    best_score = s;
                    best = p.pod;
                }
            }
            best
        };
        match self.policy {
            Policy::Random => {
                let n = ready().count();
                let k = self.rng.below(n as u64) as usize;
                Some(ready().nth(k).unwrap().pod)
            }
            Policy::Throughput => Some(pick_min(&|p| p.stats.tokens_per_s)),
            Policy::LeastRequest => {
                Some(pick_min(&|p| (p.stats.waiting + p.stats.running) as f64))
            }
            Policy::LeastKvCache => Some(pick_min(&|p| p.stats.kv_utilization)),
            Policy::LeastLatency => {
                // Completion-latency is a lagging signal: a pod looks fast
                // until its flood of queued requests completes. Outlier
                // ejection (skip pods at >2x cluster-min in-flight) prevents
                // the herd; ties fall back to queue depth.
                let min_load = pods
                    .iter()
                    .filter(|p| p.ready)
                    .map(|p| p.stats.waiting + p.stats.running)
                    .min()
                    .unwrap_or(0);
                let eligible: Vec<&PodSnapshot> = pods
                    .iter()
                    .filter(|p| {
                        p.ready && p.stats.waiting + p.stats.running <= min_load * 2 + 4
                    })
                    .collect();
                eligible
                    .iter()
                    .min_by(|a, b| {
                        a.stats
                            .avg_latency_us
                            .partial_cmp(&b.stats.avg_latency_us)
                            .unwrap()
                            .then_with(|| {
                                (a.stats.waiting + a.stats.running)
                                    .cmp(&(b.stats.waiting + b.stats.running))
                            })
                    })
                    .map(|p| p.pod)
            }
            Policy::PrefixCacheAware { threshold } => {
                // Among pods whose cache covers >= threshold of the prompt,
                // take the least loaded (cache affinity without hotspots);
                // an overloaded warm pod (>2x cluster-min in-flight) loses
                // its affinity claim. Otherwise least-request.
                let min_load = pods
                    .iter()
                    .filter(|p| p.ready)
                    .map(|p| p.stats.waiting + p.stats.running)
                    .min()
                    .unwrap_or(0);
                let warm = pods
                    .iter()
                    .filter(|p| {
                        p.ready
                            && p.prefix_hit_fraction() >= threshold
                            && p.stats.waiting + p.stats.running <= min_load * 2 + 4
                    })
                    .min_by_key(|p| p.stats.waiting + p.stats.running);
                match warm {
                    Some(p) => Some(p.pod),
                    None => Some(pick_min(&|p| (p.stats.waiting + p.stats.running) as f64)),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pod: usize) -> PodSnapshot {
        PodSnapshot {
            pod,
            ready: true,
            stats: EngineStats::default(),
            prefix_match_blocks: 0,
            prompt_blocks: 10,
            resident_adapters: vec![],
        }
    }

    fn req() -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![0; 160],
            output_len: 1,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
        }
    }

    #[test]
    fn random_covers_all_ready_pods() {
        let mut r = Router::new(Policy::Random, 3);
        let pods = vec![snap(0), snap(1), snap(2)];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[r.select(&req(), &pods).unwrap()] = true;
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn skips_not_ready() {
        let mut r = Router::new(Policy::Random, 3);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].ready = false;
        for _ in 0..50 {
            assert_eq!(r.select(&req(), &pods), Some(1));
        }
    }

    #[test]
    fn least_request_picks_idle() {
        let mut r = Router::new(Policy::LeastRequest, 1);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[0].stats.waiting = 5;
        pods[1].stats.running = 2;
        assert_eq!(r.select(&req(), &pods), Some(2));
    }

    #[test]
    fn least_kv_cache() {
        let mut r = Router::new(Policy::LeastKvCache, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.kv_utilization = 0.9;
        pods[1].stats.kv_utilization = 0.2;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn least_latency() {
        let mut r = Router::new(Policy::LeastLatency, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.avg_latency_us = 50_000.0;
        pods[1].stats.avg_latency_us = 250_000.0;
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn throughput_picks_lowest() {
        let mut r = Router::new(Policy::Throughput, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.tokens_per_s = 4_000.0;
        pods[1].stats.tokens_per_s = 100.0;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_prefers_hit_above_threshold() {
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.3 }, 1);
        let mut pods = vec![snap(0), snap(1), snap(2)];
        pods[1].prefix_match_blocks = 8; // 80% hit
        pods[1].stats.waiting = 3; // moderately loaded: affinity holds
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_overload_guard_breaks_affinity() {
        // A warm pod far above the cluster minimum loses its claim — cache
        // affinity must not create hotspots.
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.3 }, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].prefix_match_blocks = 10; // 100% hit
        pods[1].stats.waiting = 20; // but 20 > 0*2 + 4
        assert_eq!(r.select(&req(), &pods), Some(0));
    }

    #[test]
    fn least_latency_outlier_ejection() {
        // The stale-signal pod (low recorded latency, huge queue) must be
        // ejected in favor of a live one.
        let mut r = Router::new(Policy::LeastLatency, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].stats.avg_latency_us = 1_000.0; // looks fast...
        pods[0].stats.waiting = 30; // ...but drowning
        pods[1].stats.avg_latency_us = 80_000.0;
        assert_eq!(r.select(&req(), &pods), Some(1));
    }

    #[test]
    fn prefix_aware_falls_back_below_threshold() {
        let mut r = Router::new(Policy::PrefixCacheAware { threshold: 0.5 }, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[0].prefix_match_blocks = 2; // 20% < 50%
        pods[0].stats.waiting = 9;
        pods[1].stats.waiting = 1;
        assert_eq!(r.select(&req(), &pods), Some(1), "fallback to least-request");
    }

    #[test]
    fn lora_affinity_prefers_warm_pod() {
        let mut r = Router::new(Policy::LeastRequest, 1);
        let mut pods = vec![snap(0), snap(1)];
        pods[1].resident_adapters = vec!["lora-x".into()];
        pods[1].stats.running = 2; // warm but slightly busier
        let mut rq = req();
        rq.adapter = Some("lora-x".into());
        assert_eq!(r.select(&rq, &pods), Some(1));
        // Unless the warm pod is overloaded.
        pods[1].stats.waiting = 50;
        assert_eq!(r.select(&rq, &pods), Some(0));
    }

    #[test]
    fn deterministic_under_seed() {
        let pods = vec![snap(0), snap(1), snap(2)];
        let picks1: Vec<_> = {
            let mut r = Router::new(Policy::Random, 42);
            (0..20).map(|_| r.select(&req(), &pods).unwrap()).collect()
        };
        let picks2: Vec<_> = {
            let mut r = Router::new(Policy::Random, 42);
            (0..20).map(|_| r.select(&req(), &pods).unwrap()).collect()
        };
        assert_eq!(picks1, picks2);
    }
}
