//! Cross-module integration tests: the paper's features working *together*
//! (failure injection + rerouting, optimizer -> autoscaler wiring, LoRA
//! controller -> engine residency, orchestration + diagnostics recovery).

use aibrix::cluster::{ClusterState, GpuKind, PodPhase};
use aibrix::diagnostics::{diagnose, Action, FailureInjector, InjectedFault};
use aibrix::engine::{EngineConfig, EngineSim, ModelSpec};
use aibrix::gateway::{PodSnapshot, Policy, Router};
use aibrix::lora::{AdapterSpec, LoraController, PodInfo};
use aibrix::optimizer::loadmonitor::LoadMonitor;
use aibrix::optimizer::profiles::{ProfileTable, Slo};
use aibrix::optimizer::GpuOptimizer;
use aibrix::orchestration::{FleetController, FleetSpec, PlacementStrategy, RayClusterSpec};
use aibrix::sim::SimTime;
use aibrix::workload::Request;

fn req(id: u64, prompt: usize, out: usize) -> Request {
    Request {
        id,
        session: 0,
        tokens: vec![(id % 64) as u32; prompt],
        output_len: out,
        arrival: 0,
        model: "m".into(),
        adapter: None,
        user: (id % 4) as u32,
        shared_prefix_len: 0,
        end_session: false,
        deadline: None,
        tier: Default::default(),
    }
}

/// Engine failure mid-run: drained requests reroute to the survivor and
/// every request still completes.
#[test]
fn engine_failure_reroutes_and_completes() {
    let ec = EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
    let mut engines = vec![EngineSim::new(0, 0, ec.clone()), EngineSim::new(1, 1, ec)];
    let mut router = Router::new(Policy::LeastRequest, 7);

    // Route 24 requests across both engines.
    for i in 0..24u64 {
        let r = req(i, 600, 8);
        let snaps: Vec<PodSnapshot> = engines
            .iter_mut()
            .map(|e| PodSnapshot {
                pod: e.id,
                ready: !e.is_failed(),
                stats: e.stats(0),
                prompt_blocks: 1,
                ..Default::default()
            })
            .collect();
        let pick = router.select(&r, &snaps).unwrap();
        engines[pick].enqueue(r);
    }

    // Run a few steps, then kill engine 0.
    let mut now: SimTime = 0;
    for _ in 0..4 {
        for e in engines.iter_mut() {
            if let Some(dt) = e.step(now, None) {
                now += dt / 2;
            }
        }
    }
    let orphans = engines[0].fail_and_drain();
    assert!(!orphans.is_empty(), "engine 0 should have had work");

    // Gateway reroutes the drained requests (engine 0 not ready).
    for r in orphans {
        let snaps: Vec<PodSnapshot> = engines
            .iter_mut()
            .map(|e| PodSnapshot {
                pod: e.id,
                ready: !e.is_failed(),
                stats: e.stats(now),
                prompt_blocks: 1,
                ..Default::default()
            })
            .collect();
        let pick = router.select(&r, &snaps).unwrap();
        assert_eq!(pick, 1, "must avoid the failed engine");
        engines[pick].enqueue(r);
    }

    // Drain.
    let mut guard = 0;
    while engines[1].has_work() {
        if let Some(dt) = engines[1].step(now, None) {
            now += dt;
        }
        guard += 1;
        assert!(guard < 100_000, "survivor stuck");
    }
    let total: usize = engines.iter().map(|e| e.completions.len()).sum();
    assert_eq!(total, 24, "every request completes despite the failure");
}

/// Diagnostics verdict drives cluster cordon; the fleet controller
/// re-provisions gangs away from the cordoned node.
#[test]
fn diagnose_cordon_reprovision_cycle() {
    let mut state = ClusterState::new();
    for _ in 0..3 {
        state.add_node(GpuKind::A100, 2, 128);
    }
    let mut fleet = FleetController::new(FleetSpec {
        name: "f".into(),
        replicas: 2,
        cluster: RayClusterSpec {
            model: "m".into(),
            gpu: GpuKind::A100,
            workers: 1,
            placement: PlacementStrategy::Pack,
        },
        generation: 1,
        max_unavailable: 1,
    });
    fleet.reconcile(0, &mut state);
    let ids: Vec<u64> = state.pods.keys().copied().collect();
    for p in ids {
        state.mark_ready(1, p);
    }
    fleet.reconcile(1, &mut state);
    assert_eq!(fleet.ready_clusters(), 2);

    // Fault on node 0 -> diagnosis demands cordon.
    let mut inj = FailureInjector::new();
    inj.inject(0, 0, InjectedFault::ClockSag);
    let verdicts = diagnose(&inj.sample(0, 0, 2));
    assert!(verdicts.iter().any(|d| d.action == Action::DrainAndCordon));
    state.fail_node(2, 0);

    // Controller heals onto nodes 1/2.
    for t in 3..8 {
        fleet.reconcile(t, &mut state);
        let pending: Vec<u64> = state
            .pods
            .values()
            .filter(|p| p.phase == PodPhase::Pending)
            .map(|p| p.id)
            .collect();
        for p in pending {
            state.mark_ready(t, p);
        }
    }
    fleet.reconcile(10, &mut state);
    assert_eq!(fleet.ready_clusters(), 2, "capacity restored");
    for c in fleet.clusters() {
        for pod in c.pods() {
            assert_ne!(state.pods[&pod].node, Some(0), "cordoned node must stay empty");
        }
    }
}

/// GPU optimizer recommendations respond to demand shifts, and cost scales
/// with demand (MetricSource behavior for the Pod Autoscaler).
#[test]
fn optimizer_tracks_demand_shift() {
    let model = ModelSpec::deepseek_coder_7b();
    let gpus = vec![GpuKind::A10, GpuKind::L20];
    let profiles = ProfileTable::build(&model, &gpus, Slo::default());
    let mut opt = GpuOptimizer::new(profiles, gpus);

    // Light demand.
    for _ in 0..20 {
        opt.monitor.record(100, 50, 1.0);
    }
    let light = opt.recommend();
    let light_cost = opt.cost_per_hour(&light);

    // 10x heavier and longer.
    let mut heavy_monitor = LoadMonitor::new();
    for _ in 0..200 {
        heavy_monitor.record(1500, 400, 1.0);
    }
    opt.monitor = heavy_monitor;
    let heavy = opt.recommend();
    let heavy_cost = opt.cost_per_hour(&heavy);

    assert!(heavy_cost > light_cost, "heavy {heavy_cost} vs light {light_cost}");
    assert!(
        heavy.get(&GpuKind::L20).copied().unwrap_or(0) > 0,
        "long-context demand must buy L20: {heavy:?}"
    );
}

/// LoRA controller placements drive engine residency and affinity routing
/// end to end.
#[test]
fn lora_controller_to_engine_affinity() {
    let mut ctl = LoraController::new(8);
    ctl.register(AdapterSpec::new("lora-x", "llama-8b"));
    let pods: Vec<PodInfo> = (0..2)
        .map(|id| PodInfo { id, base_model: "llama-8b".into(), ready: true })
        .collect();
    ctl.reconcile(&pods);
    let endpoints = ctl.endpoints("lora-x");
    assert_eq!(endpoints.len(), 1);
    let warm_pod = endpoints[0] as usize;

    // Engines: warm pod preloads the adapter (sidecar applying the action).
    let mut ec = EngineConfig::new(GpuKind::A10, ModelSpec::llama_8b());
    ec.max_loras = 8;
    let mut engines = vec![EngineSim::new(0, 0, ec.clone()), EngineSim::new(1, 1, ec)];
    let mut warm_req = req(0, 64, 2);
    warm_req.adapter = Some("lora-x".into());
    engines[warm_pod].enqueue(warm_req);
    let mut now = 0;
    while engines[warm_pod].has_work() {
        now += engines[warm_pod].step(now, None).unwrap();
    }
    assert_eq!(engines[warm_pod].resident_adapters(), &["lora-x".to_string()]);

    // Router follows residency.
    let mut router = Router::new(Policy::LeastRequest, 1);
    let mut r = req(1, 64, 2);
    r.adapter = Some("lora-x".into());
    let snaps: Vec<PodSnapshot> = engines
        .iter_mut()
        .map(|e| PodSnapshot {
            pod: e.id,
            stats: e.stats(now),
            prompt_blocks: 1,
            resident_adapters: e.resident_adapters().to_vec(),
            ..Default::default()
        })
        .collect();
    assert_eq!(router.select(&r, &snaps), Some(warm_pod));
}

/// AI runtime: unified config produces coherent flags for all vendors and
/// cold-start decisions steer pods to warm nodes.
#[test]
fn airuntime_cold_start_and_adapters() {
    use aibrix::airuntime::adapter::{adapter_for, EngineVendor, UnifiedConfig};
    use aibrix::airuntime::{ColdStartManager, Tier};

    let cfg = UnifiedConfig {
        model: "llama-8b".into(),
        enable_prefix_caching: true,
        ..Default::default()
    };
    for &v in EngineVendor::all() {
        assert!(!adapter_for(v).launch_args(&cfg).is_empty());
    }

    let mut csm = ColdStartManager::new(true);
    csm.on_loaded("llama-8b", 2, 0);
    let weights = ModelSpec::llama_8b().weights_bytes();
    assert_eq!(csm.fastest_node("llama-8b", &[0, 1, 2], weights), Some(2));
    assert_eq!(csm.store.best_tier("llama-8b", 2), Tier::Dram);
    // Streaming loader beats the disk path for the cold nodes.
    let legacy = ColdStartManager::new(false);
    assert!(csm.load_time_us("llama-8b", 0, weights) < legacy.load_time_us("llama-8b", 0, weights));
}
