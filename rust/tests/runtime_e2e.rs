//! PJRT runtime integration tests.
//!
//! `harness = false`: xla_extension 0.5.1 must be driven from the process
//! main thread (see rust/src/runtime/mod.rs THREADING note), so this binary
//! runs its checks sequentially instead of under libtest's per-test
//! threads. Skips cleanly when artifacts are missing (run `make artifacts`).

use std::path::PathBuf;

use aibrix::runtime::{Manifest, TinyLmRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        None
    }
}

fn main() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("runtime_e2e: SKIP (no artifacts; run `make artifacts`)");
        return;
    };

    // One client/runtime for the whole binary: xla_extension is unreliable
    // across repeated client create/destroy cycles in one process.
    let rt = TinyLmRuntime::load(&dir).unwrap();

    let mut passed = 0;
    let mut run = |name: &str, f: &dyn Fn(&PathBuf, &TinyLmRuntime)| {
        f(&dir, &rt);
        println!("runtime_e2e::{name} ... ok");
        passed += 1;
    };

    run("manifest_parses", &|dir, _rt| {
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.cfg.vocab, 512);
        assert_eq!(m.cfg.max_seq, 160);
        assert!(m.artifacts.iter().any(|a| a.kind == "prefill"));
        assert!(m.artifacts.iter().any(|a| a.kind == "decode"));
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), 34); // embed + 4 layers x 8 + ln_f
    });

    run("load_exposes_batches", &|_dir, rt| {
        assert_eq!(rt.prefill_batches(), vec![1, 4]);
        assert_eq!(rt.decode_batches(), vec![1, 4, 8]);
        assert_eq!(rt.prefill_seq(1), Some(128));
    });

    run("generate_deterministic", &|_dir, rt| {
        let prompts = vec![vec![1u32, 2, 3, 4, 5, 6, 7, 8]];
        let a = rt.generate(&prompts, 8).unwrap();
        let b = rt.generate(&prompts, 8).unwrap();
        assert_eq!(a, b, "greedy decode must be deterministic");
        assert_eq!(a[0].len(), 8);
        assert!(a[0].iter().all(|&t| t < 512));
    });

    run("batch4_rows_independent", &|_dir, rt| {
        let p1 = vec![10u32, 20, 30, 40];
        let solo = rt.generate(&[p1.clone()].to_vec(), 4).unwrap();
        let batch = rt
            .generate(
                &vec![p1.clone(), vec![9u32; 12], vec![100u32, 200], vec![7u32; 30]],
                4,
            )
            .unwrap();
        assert_eq!(batch[0], solo[0], "row 0 must not depend on other rows");
    });

    run("prefill_decode_consistency", &|_dir, rt| {
        // Greedy continuation of prefill logits must chain into decode: the
        // first generated token comes from prefill's last-position logits,
        // subsequent ones from decode steps; re-running with the prompt
        // extended by the first token must agree on the next one.
        let prompt = vec![5u32, 9, 13, 2, 40, 7];
        let gen = rt.generate(&[prompt.clone()].to_vec(), 3).unwrap();
        let mut longer = prompt.clone();
        longer.push(gen[0][0]);
        let gen2 = rt.generate(&[longer].to_vec(), 2).unwrap();
        assert_eq!(gen2[0][0], gen[0][1], "KV-cache decode must match re-prefill");
    });

    run("error_paths", &|_dir, rt| {
        assert!(rt.prefill(1, &[0i32; 7]).is_err(), "bad token count");
        assert!(rt.prefill(3, &[0i32; 3 * 128]).is_err(), "no batch-3 artifact");
        assert!(
            rt.generate(&[vec![1u32; 300]].to_vec(), 4).is_err(),
            "prompt longer than prefill window"
        );
        assert!(
            rt.generate(&[vec![1u32; 8]].to_vec(), 100).is_err(),
            "steps beyond cache headroom"
        );
    });

    println!("runtime_e2e: {passed} checks passed");
}
