//! Runtime integration tests.
//!
//! `harness = false` (kept from the xla_extension era: the binary drives
//! its checks sequentially from the process main thread). Two sections:
//!
//! 1. Kernel-layer properties on synthetic runtimes — always run, no
//!    artifacts needed: the kernel path must be bit-identical to the
//!    retained scalar reference (`runtime/reference.rs`) on random
//!    (batch, seq, token) inputs, and thread count must never change bits.
//! 2. Artifact-backed checks — skip cleanly when `make artifacts` hasn't
//!    been run.

use std::path::{Path, PathBuf};

use aibrix::pt::forall;
use aibrix::runtime::kernels;
use aibrix::runtime::{Manifest, ModelCfg, Precision, SyntheticSpec, TinyLmRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        None
    }
}

// ------------------------------------------------- kernel-layer properties

const PROP_VOCAB: usize = 32;
const PROP_SEQ: usize = 10;

fn prop_spec() -> SyntheticSpec {
    SyntheticSpec {
        cfg: ModelCfg {
            vocab: PROP_VOCAB,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            head_dim: 8,
            max_seq: 24,
            page_size: 4,
        },
        d_ff: 32,
        prefill: vec![(1, PROP_SEQ), (2, PROP_SEQ), (3, PROP_SEQ)],
        decode: vec![1, 2, 3],
        seed: 11,
    }
}

/// Proptest runtime pinned to the f32 contract tier (a stray
/// `AIBRIX_RT_PRECISION` must not flip the bit-exact props onto the quant
/// path); the int8-tier props call `set_precision(Precision::Int8)` on top.
fn prop_runtime(threads: usize) -> TinyLmRuntime {
    let mut rt = TinyLmRuntime::synthetic(&prop_spec());
    rt.set_threads(threads);
    rt.set_precision(Precision::F32);
    rt
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Random (batch, tokens, next-token, positions) case for the proptests.
#[derive(Debug)]
struct Case {
    batch: usize,
    tokens: Vec<i32>,
    next: Vec<i32>,
    prompt_lens: Vec<usize>,
}

fn gen_case(rng: &mut aibrix::util::Rng, _size: aibrix::pt::Size) -> Case {
    let batch = 1 + rng.below(3) as usize;
    let tokens: Vec<i32> =
        (0..batch * PROP_SEQ).map(|_| rng.below(PROP_VOCAB as u64) as i32).collect();
    let next: Vec<i32> = (0..batch).map(|_| rng.below(PROP_VOCAB as u64) as i32).collect();
    let prompt_lens: Vec<usize> =
        (0..batch).map(|_| 1 + rng.below(PROP_SEQ as u64) as usize).collect();
    Case { batch, tokens, next, prompt_lens }
}

fn kernel_properties() {
    // Kernel prefill == scalar reference, bit for bit (logits and caches).
    forall("kernel-prefill-matches-reference", 25, gen_case, |c| {
        let rt = prop_runtime(4);
        let a = rt.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let b = rt.prefill_reference(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        if !bits_eq(&a.logits, &b.logits) {
            return Err("prefill logits diverge from reference".into());
        }
        if !bits_eq(&a.k.data, &b.k.data) || !bits_eq(&a.v.data, &b.v.data) {
            return Err("prefill KV cache diverges from reference".into());
        }
        Ok(())
    });
    println!("runtime_e2e::prop_kernel_prefill_matches_reference ... ok");

    // Kernel decode == scalar reference after a shared prefill.
    forall("kernel-decode-matches-reference", 25, gen_case, |c| {
        let rt = prop_runtime(4);
        let pre = rt.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let pos: Vec<i32> = c.prompt_lens.iter().map(|&l| l as i32).collect();
        let a = rt
            .decode(c.batch, &c.next, &pos, pre.k.clone(), pre.v.clone())
            .map_err(|e| e.to_string())?;
        let b = rt
            .decode_reference(c.batch, &c.next, &pos, pre.k.clone(), pre.v.clone())
            .map_err(|e| e.to_string())?;
        if !bits_eq(&a.logits, &b.logits) {
            return Err("decode logits diverge from reference".into());
        }
        if !bits_eq(&a.k.data, &b.k.data) || !bits_eq(&a.v.data, &b.v.data) {
            return Err("decode KV cache diverges from reference".into());
        }
        Ok(())
    });
    println!("runtime_e2e::prop_kernel_decode_matches_reference ... ok");

    // Thread count never changes bits: multi-threaded == AIBRIX_RT_THREADS=1.
    forall("threaded-matches-single-thread", 25, gen_case, |c| {
        let rt1 = prop_runtime(1);
        let rt8 = prop_runtime(8);
        let a = rt1.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let b = rt8.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        if !bits_eq(&a.logits, &b.logits) || !bits_eq(&a.k.data, &b.k.data) {
            return Err("prefill bits depend on thread count".into());
        }
        let prompts: Vec<Vec<u32>> =
            c.prompt_lens.iter().map(|&l| (0..l as u32).collect()).collect();
        let g1 = rt1.generate(&prompts, 4).map_err(|e| e.to_string())?;
        let g8 = rt8.generate(&prompts, 4).map_err(|e| e.to_string())?;
        if g1 != g8 {
            return Err(format!("generate depends on thread count: {g1:?} vs {g8:?}"));
        }
        Ok(())
    });
    println!("runtime_e2e::prop_threaded_matches_single_thread ... ok");

    // Seeded prefill — KV for a block-aligned prefix installed from an
    // earlier prefill via the pool's extract/assemble block format — is
    // bit-identical to full re-prefill: same last-position logits, same
    // K/V caches. This is the golden contract cross-replica reuse rides on.
    forall("seeded-prefill-matches-full-reprefill", 25, gen_case, |c| {
        use aibrix::kvcache::blocks::{assemble_prefix, extract_block, KvBlockData, KvBlockShape};
        use aibrix::runtime::SeededPrefix;
        use std::sync::Arc;

        let rt = prop_runtime(4);
        let spec = prop_spec();
        let bt = 2usize;
        let shape = KvBlockShape {
            n_layers: spec.cfg.n_layers,
            block_tokens: bt,
            d_model: spec.cfg.d_model,
        };
        let full = rt.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let lasts: Vec<usize> = c.prompt_lens.iter().map(|&l| l - 1).collect();
        let cold =
            rt.prefill_last(c.batch, &c.tokens, &lasts, None).map_err(|e| e.to_string())?;
        // Per row: cache the longest block-aligned prefix below the last
        // position, exactly as the engine's admission hook does.
        let slabs: Vec<(usize, Vec<f32>, Vec<f32>)> = (0..c.batch)
            .map(|b| {
                let blocks = lasts[b] / bt;
                let chain: Vec<Arc<KvBlockData>> = (0..blocks)
                    .map(|i| {
                        Arc::new(extract_block(
                            &full.k.data,
                            &full.v.data,
                            &shape,
                            c.batch,
                            spec.cfg.max_seq,
                            b,
                            i,
                        ))
                    })
                    .collect();
                let (k, v) = assemble_prefix(&chain, &shape);
                (blocks * bt, k, v)
            })
            .collect();
        let seeds: Vec<SeededPrefix> = slabs
            .iter()
            .map(|(len, k, v)| SeededPrefix { len: *len, k, v })
            .collect();
        let warm = rt
            .prefill_last_seeded(c.batch, &c.tokens, &lasts, None, &seeds)
            .map_err(|e| e.to_string())?;
        for b in 0..c.batch {
            if !bits_eq(warm.logits_of(b), cold.logits_of(b)) {
                return Err(format!("row {b}: seeded logits diverge from cold prefill"));
            }
        }
        if !bits_eq(&warm.k.data, &full.k.data) || !bits_eq(&warm.v.data, &full.v.data) {
            return Err("seeded KV caches diverge from full re-prefill".into());
        }
        Ok(())
    });
    println!("runtime_e2e::prop_seeded_prefill_matches_full_reprefill ... ok");

    // ---- relaxed-exactness tier (int8 quantized weights + simd kernels).

    /// Random GEMM shapes for the quant/simd kernel properties.
    #[derive(Debug)]
    struct GemmCase {
        m: usize,
        k: usize,
        n: usize,
        x: Vec<f32>,
        w: Vec<f32>,
    }

    fn gen_gemm(rng: &mut aibrix::util::Rng, _size: aibrix::pt::Size) -> GemmCase {
        // Sizes straddle the (MC=32, KC=128) tile boundaries and the
        // 8-wide simd lanes (odd n exercises the scalar tail).
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(160) as usize;
        let n = 1 + rng.below(48) as usize;
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        GemmCase { m, k, n, x, w }
    }

    // gemm_i8 stays within the documented error bound of the f32 gemm:
    // per output element, quantization contributes at most
    // scale_j/2 * sum|x| (round-to-nearest per weight) and f32 summation
    // order at most a few ULPs of the magnitude sum — 0.6 * scale * sum|x|
    // plus a small absolute slack covers both with margin.
    forall("gemm-i8-error-bounded-vs-f32", 25, gen_gemm, |c| {
        let q = kernels::quantize_cols(&c.w, c.k, c.n);
        let mut qa = vec![0.0f32; c.m * c.n];
        let mut panel = Vec::new();
        kernels::gemm_i8(&c.x, &q, c.m, c.k, c.n, &mut qa, &mut panel);
        let mut fa = vec![0.0f32; c.m * c.n];
        kernels::gemm(&c.x, &c.w, c.m, c.k, c.n, &mut fa);
        for i in 0..c.m {
            let sx: f32 = c.x[i * c.k..(i + 1) * c.k].iter().map(|v| v.abs()).sum();
            for j in 0..c.n {
                let bound = 0.6 * q.scales[j] * sx + 1e-5;
                let diff = (qa[i * c.n + j] - fa[i * c.n + j]).abs();
                if diff > bound {
                    return Err(format!(
                        "({i},{j}): |{} - {}| = {diff} exceeds bound {bound}",
                        qa[i * c.n + j],
                        fa[i * c.n + j]
                    ));
                }
            }
        }
        Ok(())
    });
    println!("runtime_e2e::prop_gemm_i8_error_bounded_vs_f32 ... ok");

    // Dispatch kernels == scalar bodies, bit for bit. Under the default
    // build this is trivially true; under `--features simd` on an AVX2
    // host it pins the vectorized kernels to the scalar contract.
    forall("simd-dispatch-matches-scalar", 25, gen_gemm, |c| {
        let mut a = vec![0.0f32; c.m * c.n];
        let mut b = vec![0.0f32; c.m * c.n];
        kernels::gemm(&c.x, &c.w, c.m, c.k, c.n, &mut a);
        kernels::gemm_scalar(&c.x, &c.w, c.m, c.k, c.n, &mut b);
        if !bits_eq(&a, &b) {
            return Err("gemm dispatch diverges from scalar".into());
        }
        let q = kernels::quantize_cols(&c.w, c.k, c.n);
        let mut panel = Vec::new();
        kernels::gemm_i8(&c.x, &q, c.m, c.k, c.n, &mut a, &mut panel);
        kernels::gemm_i8_scalar(&c.x, &q, c.m, c.k, c.n, &mut b, &mut panel);
        if !bits_eq(&a, &b) {
            return Err("gemm_i8 dispatch diverges from scalar".into());
        }
        let mut na = vec![0.0f32; c.k];
        let mut nb = vec![0.0f32; c.k];
        let g = &c.w[..c.k];
        kernels::rms_norm(&c.x[..c.k], g, &mut na);
        kernels::rms_norm_scalar(&c.x[..c.k], g, &mut nb);
        if !bits_eq(&na, &nb) {
            return Err("rms_norm dispatch diverges from scalar".into());
        }
        // Treat w as [n rows, k wide] embedding for the logits tile.
        let mut la = vec![0.0f32; c.n];
        let mut lb = vec![0.0f32; c.n];
        kernels::logits_tile(&c.x[..c.k], &c.w, 0, c.n, &mut la);
        kernels::logits_tile_scalar(&c.x[..c.k], &c.w, 0, c.n, &mut lb);
        if !bits_eq(&la, &lb) {
            return Err("logits_tile dispatch diverges from scalar".into());
        }
        Ok(())
    });
    println!("runtime_e2e::prop_simd_dispatch_matches_scalar ... ok");

    // Thread count never changes bits inside the int8 tier either — the
    // relaxed contract is vs f32, not vs determinism.
    forall("int8-threaded-matches-single-thread", 25, gen_case, |c| {
        let mut rt1 = prop_runtime(1);
        rt1.set_precision(Precision::Int8);
        let mut rt8 = prop_runtime(8);
        rt8.set_precision(Precision::Int8);
        let a = rt1.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let b = rt8.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        if !bits_eq(&a.logits, &b.logits) || !bits_eq(&a.k.data, &b.k.data) {
            return Err("int8 prefill bits depend on thread count".into());
        }
        let prompts: Vec<Vec<u32>> =
            c.prompt_lens.iter().map(|&l| (0..l as u32).collect()).collect();
        let g1 = rt1.generate(&prompts, 4).map_err(|e| e.to_string())?;
        let g8 = rt8.generate(&prompts, 4).map_err(|e| e.to_string())?;
        if g1 != g8 {
            return Err(format!("int8 generate depends on thread count: {g1:?} vs {g8:?}"));
        }
        Ok(())
    });
    println!("runtime_e2e::prop_int8_threaded_matches_single_thread ... ok");

    // Int8 KV-decode self-consistency: within the tier, decoding from the
    // cache must still chain bit-exactly into re-prefill (same ascending-k
    // kernels, same m-split invariance — quantization relaxes nothing
    // here).
    forall("int8-decode-matches-re-prefill", 25, gen_case, |c| {
        let mut rt = prop_runtime(4);
        rt.set_precision(Precision::Int8);
        let prompt: Vec<u32> = (0..c.prompt_lens[0] as u32).collect();
        let gen = rt.generate(&[prompt.clone()].to_vec(), 3).map_err(|e| e.to_string())?;
        let mut longer = prompt;
        longer.push(gen[0][0]);
        if longer.len() > PROP_SEQ {
            return Ok(()); // no room to re-prefill the extended prompt
        }
        let gen2 = rt.generate(&[longer].to_vec(), 2).map_err(|e| e.to_string())?;
        if gen2[0][0] != gen[0][1] {
            return Err(format!(
                "int8 KV decode diverges from re-prefill: {} vs {}",
                gen2[0][0], gen[0][1]
            ));
        }
        Ok(())
    });
    println!("runtime_e2e::prop_int8_decode_matches_re_prefill ... ok");

    // E2E greedy agreement across tiers: int8 may flip near-ties, but the
    // first sampled token must agree with the f32 path far above chance
    // (1/vocab ~ 3%) in aggregate. Per-case failures are expected and
    // allowed; the aggregate rate is the contract.
    let agree = std::cell::Cell::new(0usize);
    let total = std::cell::Cell::new(0usize);
    forall("int8-top1-agreement-sample", 25, gen_case, |c| {
        let rt = prop_runtime(2);
        let mut rtq = prop_runtime(2);
        rtq.set_precision(Precision::Int8);
        let lasts: Vec<usize> = c.prompt_lens.iter().map(|&l| l - 1).collect();
        let a = rt.prefill_last(c.batch, &c.tokens, &lasts, None).map_err(|e| e.to_string())?;
        let b = rtq.prefill_last(c.batch, &c.tokens, &lasts, None).map_err(|e| e.to_string())?;
        for row in 0..c.batch {
            total.set(total.get() + 1);
            if a.argmax_of(row) == b.argmax_of(row) {
                agree.set(agree.get() + 1);
            }
        }
        Ok(())
    });
    let rate = agree.get() as f64 / total.get().max(1) as f64;
    assert!(
        rate >= 0.5,
        "int8 top-1 agreement {rate:.2} over {} rows is below the 0.5 contract floor",
        total.get()
    );
    println!(
        "runtime_e2e::prop_int8_top1_agreement ... ok ({rate:.2} over {} rows)",
        total.get()
    );

    // The positions-mask fast path is a pure subset of full prefill.
    forall("prefill-last-is-subset", 25, gen_case, |c| {
        let rt = prop_runtime(4);
        let full = rt.prefill(c.batch, &c.tokens).map_err(|e| e.to_string())?;
        let lasts: Vec<usize> = c.prompt_lens.iter().map(|&l| l - 1).collect();
        let fast =
            rt.prefill_last(c.batch, &c.tokens, &lasts, None).map_err(|e| e.to_string())?;
        for b in 0..c.batch {
            if !bits_eq(fast.logits_of(b), full.logits_at(b, lasts[b])) {
                return Err(format!("row {b}: prefill_last logits diverge"));
            }
        }
        if !bits_eq(&fast.k.data, &full.k.data) || !bits_eq(&fast.v.data, &full.v.data) {
            return Err("prefill_last KV cache diverges".into());
        }
        Ok(())
    });
    println!("runtime_e2e::prop_prefill_last_is_subset ... ok");
}

// --------------------------------------------------- artifact-backed checks

fn artifact_checks(dir: &Path) {
    let mut rt = TinyLmRuntime::load(dir).unwrap();
    // The artifact checks include kernel-vs-reference bit equality: pin f32.
    rt.set_precision(Precision::F32);

    let mut passed = 0;
    let mut run = |name: &str, f: &dyn Fn(&Path, &TinyLmRuntime)| {
        f(dir, &rt);
        println!("runtime_e2e::{name} ... ok");
        passed += 1;
    };

    run("manifest_parses", &|dir, _rt| {
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.cfg.vocab, 512);
        assert_eq!(m.cfg.max_seq, 160);
        assert!(m.artifacts.iter().any(|a| a.kind == "prefill"));
        assert!(m.artifacts.iter().any(|a| a.kind == "decode"));
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), 34); // embed + 4 layers x 8 + ln_f
    });

    run("load_exposes_batches", &|_dir, rt| {
        assert_eq!(rt.prefill_batches(), vec![1, 4]);
        assert_eq!(rt.decode_batches(), vec![1, 4, 8]);
        assert_eq!(rt.prefill_seq(1), Some(128));
    });

    run("generate_deterministic", &|_dir, rt| {
        let prompts = vec![vec![1u32, 2, 3, 4, 5, 6, 7, 8]];
        let a = rt.generate(&prompts, 8).unwrap();
        let b = rt.generate(&prompts, 8).unwrap();
        assert_eq!(a, b, "greedy decode must be deterministic");
        assert_eq!(a[0].len(), 8);
        assert!(a[0].iter().all(|&t| t < 512));
    });

    run("batch4_rows_independent", &|_dir, rt| {
        let p1 = vec![10u32, 20, 30, 40];
        let solo = rt.generate(&[p1.clone()].to_vec(), 4).unwrap();
        let batch = rt
            .generate(
                &vec![p1.clone(), vec![9u32; 12], vec![100u32, 200], vec![7u32; 30]],
                4,
            )
            .unwrap();
        assert_eq!(batch[0], solo[0], "row 0 must not depend on other rows");
    });

    run("prefill_decode_consistency", &|_dir, rt| {
        // Greedy continuation of prefill logits must chain into decode: the
        // first generated token comes from prefill's last-position logits,
        // subsequent ones from decode steps; re-running with the prompt
        // extended by the first token must agree on the next one.
        let prompt = vec![5u32, 9, 13, 2, 40, 7];
        let gen = rt.generate(&[prompt.clone()].to_vec(), 3).unwrap();
        let mut longer = prompt.clone();
        longer.push(gen[0][0]);
        let gen2 = rt.generate(&[longer].to_vec(), 2).unwrap();
        assert_eq!(gen2[0][0], gen[0][1], "KV-cache decode must match re-prefill");
    });

    run("kernel_matches_reference_on_artifacts", &|_dir, rt| {
        // The real model's weights, not just synthetic ones, must agree
        // between kernel and scalar reference paths.
        let tokens: Vec<i32> = (0..128).map(|i| (i * 37) % 512).collect();
        let a = rt.prefill(1, &tokens).unwrap();
        let b = rt.prefill_reference(1, &tokens).unwrap();
        assert!(
            a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits()),
            "artifact-model kernel logits diverge from reference"
        );
    });

    run("error_paths", &|_dir, rt| {
        assert!(rt.prefill(1, &[0i32; 7]).is_err(), "bad token count");
        assert!(rt.prefill(3, &[0i32; 3 * 128]).is_err(), "no batch-3 artifact");
        assert!(
            rt.generate(&[vec![1u32; 300]].to_vec(), 4).is_err(),
            "prompt longer than prefill window"
        );
        assert!(
            rt.generate(&[vec![1u32; 8]].to_vec(), 100).is_err(),
            "steps beyond cache headroom"
        );
    });

    println!("runtime_e2e: {passed} artifact checks passed");
}

fn main() {
    kernel_properties();

    match artifacts_dir() {
        Some(dir) => artifact_checks(&dir),
        None => eprintln!("runtime_e2e: artifact checks SKIPPED (run `make artifacts`)"),
    }
}
