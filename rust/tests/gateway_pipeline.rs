//! Property tests for the gateway scoring pipeline (ISSUE 1 invariants):
//!
//!   1. single-scorer presets at weight 1.0 route identically to the
//!      legacy closed-enum policies (ported below as the reference),
//!   2. the selected pod is always `ready` (and None iff none is),
//!   3. the prefix-affinity score is monotone non-decreasing in
//!      `prefix_match_blocks`,
//!   4. decisions are deterministic and stable under scratch reuse.

use aibrix::engine::EngineStats;
use aibrix::gateway::{PipelineConfig, PodSnapshot, Policy, Router, ScoreCtx, ScoringPipeline};
use aibrix::pt::{forall, gen};
use aibrix::workload::Request;

fn req() -> Request {
    Request {
        id: 0,
        session: 0,
        tokens: vec![1; 160],
        output_len: 4,
        arrival: 0,
        model: "m".into(),
        adapter: None,
        user: 0,
        shared_prefix_len: 0,
        end_session: false,
        deadline: None,
        tier: Default::default(),
    }
}

/// Raw pod signal tuple the generators produce:
/// (ready, load, kv_util, latency_us, prefix_match_blocks).
type PodSig = (bool, usize, f64, f64, usize);

fn snapshots(sigs: &[PodSig]) -> Vec<PodSnapshot> {
    sigs.iter()
        .enumerate()
        .map(|(i, &(ready, load, kv, lat, pmb))| PodSnapshot {
            pod: i,
            ready,
            stats: EngineStats {
                waiting: load,
                running: load / 2,
                kv_utilization: kv,
                tokens_per_s: lat / 100.0,
                avg_latency_us: lat,
                prefix_hit_rate: kv,
                ..Default::default()
            },
            prefix_match_blocks: pmb,
            prompt_blocks: 10,
            // ClusterView signals, derived from the same raw tuple so the
            // weighted props exercise every scorer without widening the
            // generator.
            pool_blocks_local: pmb / 2,
            pool_blocks_total: pmb,
            session_match: load % 3 == 0,
            slo_headroom: kv,
            resident_adapters: vec![],
            health: Default::default(),
        })
        .collect()
}

fn gen_pods(rng: &mut aibrix::util::Rng, max_pods: usize) -> Vec<PodSig> {
    let n = 1 + gen::usize_up_to(rng, max_pods);
    (0..n)
        .map(|_| {
            (
                rng.chance(0.8),
                gen::usize_up_to(rng, 50),
                rng.uniform(0.0, 1.0),
                rng.uniform(1.0, 500_000.0),
                gen::usize_up_to(rng, 14),
            )
        })
        .collect()
}

/// The pre-pipeline router, ported verbatim from the seed's closed enum
/// match (minus Random): the behavioral reference the presets must match.
fn legacy_select(policy: Policy, pods: &[PodSnapshot]) -> Option<usize> {
    if !pods.iter().any(|p| p.ready) {
        return None;
    }
    let pick_min = |key: &dyn Fn(&PodSnapshot) -> f64| -> usize {
        let mut best = usize::MAX;
        let mut best_score = f64::INFINITY;
        for p in pods.iter().filter(|p| p.ready) {
            let s = key(p);
            if s < best_score {
                best_score = s;
                best = p.pod;
            }
        }
        best
    };
    match policy {
        Policy::Throughput => Some(pick_min(&|p| p.stats.tokens_per_s)),
        Policy::LeastRequest => Some(pick_min(&|p| (p.stats.waiting + p.stats.running) as f64)),
        Policy::LeastKvCache => Some(pick_min(&|p| p.stats.kv_utilization)),
        Policy::LeastLatency => {
            let min_load = pods
                .iter()
                .filter(|p| p.ready)
                .map(|p| p.stats.waiting + p.stats.running)
                .min()
                .unwrap_or(0);
            let eligible: Vec<&PodSnapshot> = pods
                .iter()
                .filter(|p| p.ready && p.stats.waiting + p.stats.running <= min_load * 2 + 4)
                .collect();
            eligible
                .iter()
                .min_by(|a, b| {
                    a.stats
                        .avg_latency_us
                        .partial_cmp(&b.stats.avg_latency_us)
                        .unwrap()
                        .then_with(|| {
                            (a.stats.waiting + a.stats.running)
                                .cmp(&(b.stats.waiting + b.stats.running))
                        })
                })
                .map(|p| p.pod)
        }
        Policy::PrefixCacheAware { threshold } => {
            let min_load = pods
                .iter()
                .filter(|p| p.ready)
                .map(|p| p.stats.waiting + p.stats.running)
                .min()
                .unwrap_or(0);
            let warm = pods
                .iter()
                .filter(|p| {
                    p.ready
                        && p.prefix_hit_fraction() >= threshold
                        && p.stats.waiting + p.stats.running <= min_load * 2 + 4
                })
                .min_by_key(|p| p.stats.waiting + p.stats.running);
            match warm {
                Some(p) => Some(p.pod),
                None => Some(pick_min(&|p| (p.stats.waiting + p.stats.running) as f64)),
            }
        }
        _ => unreachable!("reference covers scoring presets only"),
    }
}

/// Invariant 1: each single-scorer preset reduces to the legacy policy.
#[test]
fn prop_presets_match_legacy_policies() {
    forall(
        "pipeline-presets-equal-legacy",
        400,
        |rng, _| {
            let pods = gen_pods(rng, 12);
            let policy_idx = gen::usize_up_to(rng, 5);
            let threshold = rng.uniform(0.0, 1.0);
            (pods, policy_idx, threshold)
        },
        |(pods, policy_idx, threshold)| {
            let snaps = snapshots(pods);
            let policy = match policy_idx {
                0 => Policy::Throughput,
                1 => Policy::LeastRequest,
                2 => Policy::LeastKvCache,
                3 => Policy::LeastLatency,
                _ => Policy::PrefixCacheAware { threshold: *threshold },
            };
            let expected = legacy_select(policy, &snaps);
            let got = Router::new(policy, 1).select(&req(), &snaps);
            if got != expected {
                return Err(format!(
                    "{}: pipeline {got:?} != legacy {expected:?}",
                    policy.name()
                ));
            }
            Ok(())
        },
    );
}

fn gen_weighted(rng: &mut aibrix::util::Rng) -> PipelineConfig {
    loop {
        let mut cfg = PipelineConfig {
            prefix_affinity: rng.uniform(0.0, 1.0),
            least_request: rng.uniform(0.0, 1.0),
            least_kv_cache: rng.uniform(0.0, 1.0),
            least_latency: rng.uniform(0.0, 1.0),
            throughput: rng.uniform(0.0, 1.0),
            lora_residency: rng.uniform(0.0, 1.0),
            fairness: rng.uniform(0.0, 1.0),
            pool_affinity: rng.uniform(0.0, 1.0),
            slo_headroom: rng.uniform(0.0, 1.0),
            session_affinity: rng.uniform(0.0, 1.0),
            prefix_threshold: rng.uniform(0.0, 1.0),
            overload_guard: rng.chance(0.5),
        };
        // Randomly zero some weights to cover sparse mixes.
        if rng.chance(0.5) {
            cfg.least_kv_cache = 0.0;
            cfg.lora_residency = 0.0;
        }
        if rng.chance(0.3) {
            cfg.least_request = 0.0;
            cfg.fairness = 0.0;
        }
        if rng.chance(0.4) {
            cfg.pool_affinity = 0.0;
            cfg.slo_headroom = 0.0;
            cfg.session_affinity = 0.0;
        }
        if cfg.validate().is_ok() {
            return cfg;
        }
    }
}

/// Invariants 2 + 4: any valid weighted mix always returns a ready pod
/// (None iff none is ready), deterministically, including under scratch
/// reuse across heterogeneous requests.
#[test]
fn prop_weighted_totality_and_determinism() {
    forall(
        "pipeline-weighted-totality",
        400,
        |rng, _| {
            let cfg = gen_weighted(rng);
            let pods = gen_pods(rng, 12);
            let share = rng.uniform(0.0, 1.0);
            (cfg, pods, share)
        },
        |(cfg, pods, share)| {
            let snaps = snapshots(pods);
            let ctx = ScoreCtx { tenant_share: *share };
            let mut pl = ScoringPipeline::new(*cfg);
            let pick1 = pl.select(&req(), &snaps, &ctx);
            let pick2 = pl.select(&req(), &snaps, &ctx); // scratch reuse
            let fresh = ScoringPipeline::new(*cfg).select(&req(), &snaps, &ctx);
            if pick1 != pick2 || pick1 != fresh {
                return Err(format!("non-deterministic: {pick1:?} {pick2:?} {fresh:?}"));
            }
            let any_ready = snaps.iter().any(|p| p.ready);
            match pick1 {
                Some(i) => {
                    let p = snaps.iter().find(|p| p.pod == i).ok_or("unknown pod")?;
                    if !p.ready {
                        return Err(format!("picked un-ready pod {i}"));
                    }
                    Ok(())
                }
                None if !any_ready => Ok(()),
                None => Err("returned None with ready pods".into()),
            }
        },
    );
}

/// Invariant 3: a pod's weighted total is monotone non-decreasing in its
/// own `prefix_match_blocks` (everything else fixed).
#[test]
fn prop_prefix_score_monotone_in_match_blocks() {
    forall(
        "pipeline-prefix-monotone",
        400,
        |rng, _| {
            let cfg = gen_weighted(rng);
            let pods = gen_pods(rng, 8);
            let which = gen::usize_up_to(rng, pods.len());
            let bump = 1 + gen::usize_up_to(rng, 10);
            (cfg, pods, which, bump)
        },
        |(cfg, pods, which, bump)| {
            let pl = ScoringPipeline::new(*cfg);
            let ctx = ScoreCtx::default();
            let snaps = snapshots(pods);
            let mut before = Vec::new();
            pl.score_into(&req(), &snaps, &ctx, &mut before);
            let mut bumped = snaps.clone();
            bumped[*which].prefix_match_blocks += *bump;
            let mut after = Vec::new();
            pl.score_into(&req(), &bumped, &ctx, &mut after);
            if snaps[*which].ready && after[*which] < before[*which] {
                return Err(format!(
                    "score dropped {} -> {} when match blocks rose by {bump}",
                    before[*which], after[*which]
                ));
            }
            Ok(())
        },
    );
}

/// Threshold parse fuzz: every float in [0,1] round-trips through
/// `prefix-cache-aware=<t>`; everything outside is rejected.
#[test]
fn prop_threshold_parse_round_trip() {
    forall(
        "policy-threshold-parse",
        300,
        |rng, _| (rng.uniform(-1.0, 2.0), rng.uniform(0.0, 1.0)),
        |&(wild, valid)| {
            let p = Policy::parse(&format!("prefix-cache-aware={valid}"))
                .map_err(|e| format!("valid threshold rejected: {e}"))?;
            let Policy::PrefixCacheAware { threshold } = p else {
                return Err("wrong policy variant".into());
            };
            if (threshold - valid).abs() > 1e-12 {
                return Err(format!("threshold {valid} round-tripped to {threshold}"));
            }
            let wild_result = Policy::parse(&format!("prefix-cache-aware={wild}"));
            if (0.0..=1.0).contains(&wild) {
                if wild_result.is_err() {
                    return Err(format!("in-range {wild} rejected"));
                }
            } else if wild_result.is_ok() {
                return Err(format!("out-of-range {wild} accepted"));
            }
            Ok(())
        },
    );
}
