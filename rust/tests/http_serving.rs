//! HTTP serving integration: real TinyLM behind the HTTP server, in-process
//! client. Skips when artifacts are absent (`make artifacts`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use aibrix::engine::real::{RealEngineHandle, RealRequest, ServeOutcome};
use aibrix::json::{parse, Json};
use aibrix::server::{http_request, Handler, HttpRequest, HttpResponse, HttpServer};
use aibrix::tokenizer::Tokenizer;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    d.join("manifest.json").exists().then_some(d)
}

#[test]
fn serves_real_completions_over_http() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let engine = RealEngineHandle::spawn(&dir).expect("engine");
    let tokenizer = Tokenizer::new(engine.vocab as u32);
    let max_prompt = engine.max_prompt;
    let ids = Arc::new(AtomicU64::new(0));

    let handler: Handler = {
        let engine = engine.clone();
        let tokenizer = tokenizer.clone();
        Arc::new(move |req: &HttpRequest| {
            if req.path != "/v1/completions" {
                return HttpResponse::text(404, "nope");
            }
            let body = parse(&req.body_str()).unwrap();
            let mut tokens = tokenizer.encode(body["prompt"].as_str().unwrap_or("x"));
            tokens.truncate(max_prompt);
            if tokens.is_empty() {
                tokens.push(tokenizer.bos());
            }
            let id = ids.fetch_add(1, Ordering::Relaxed);
            let out = engine
                .serve(RealRequest { id, tokens, max_new_tokens: 4, ..Default::default() })
                .unwrap();
            let ServeOutcome::Done(c) = out else {
                panic!("deadline-free request must never be shed");
            };
            HttpResponse::json(
                200,
                &Json::obj([
                    ("tokens", Json::arr(c.generated.iter().map(|&t| Json::from(t as u64)))),
                    ("latency_us", Json::from(c.latency_us())),
                ])
                .to_string(),
            )
        })
    };
    let server = HttpServer::start("127.0.0.1:0", 2, handler).unwrap();
    let addr = server.addr();

    // Two identical prompts must produce identical (greedy) tokens; a
    // different prompt should generally differ.
    let ask = |prompt: &str| -> Vec<u64> {
        let body = format!(r#"{{"prompt":"{prompt}","max_tokens":4}}"#);
        let (code, resp) = http_request(&addr, "POST", "/v1/completions", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
        let j = parse(&resp).unwrap();
        j["tokens"]
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_u64().unwrap())
            .collect()
    };
    let a1 = ask("SELECT count(*) FROM users;");
    let a2 = ask("SELECT count(*) FROM users;");
    assert_eq!(a1, a2, "greedy decoding over HTTP must be deterministic");
    assert_eq!(a1.len(), 4);
    engine.stop();
}
