//! Property-based tests over the DESIGN.md §9 invariants, using the
//! in-repo `pt` mini-framework (seeded, reproducible via AIBRIX_PT_SEED).

use aibrix::cluster::GpuKind;
use aibrix::engine::prefix::{prompt_block_keys, PrefixCache};
use aibrix::engine::{BlockAllocator, EngineConfig, EngineSim, ModelSpec};
use aibrix::gateway::{FairQueue, PodSnapshot, Policy, Router};
use aibrix::json::{parse, Json};
use aibrix::kvcache::{EvictionKind, EvictionPolicy};
use aibrix::metrics::Histogram;
use aibrix::pt::{forall, gen, Size};
use aibrix::sim::Simulator;
use aibrix::util::{percentile, Rng};
use aibrix::workload::Request;

// -------------------------------------------------------- block allocator

/// Random legal op sequences never violate the allocator's three-state
/// invariant, and counts always add up.
#[test]
fn prop_block_allocator_state_machine() {
    forall(
        "block-allocator-states",
        200,
        |rng, size| {
            let ops: Vec<u32> = (0..size.0 * 4).map(|_| rng.next_u32()).collect();
            ops
        },
        |ops| {
            let mut a = BlockAllocator::new(32, 16);
            let mut live: Vec<u32> = vec![];
            let mut cached: Vec<u32> = vec![];
            for &op in ops {
                match op % 5 {
                    0 => {
                        if let Some(b) = a.alloc() {
                            live.push(b);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let b = live.swap_remove((op / 8) as usize % live.len());
                            a.release(b);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let b = live[(op / 8) as usize % live.len()];
                            a.retain(b);
                            a.release(b); // paired: net zero
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let b = live.swap_remove((op / 8) as usize % live.len());
                            if a.release_cached(b) {
                                cached.push(b);
                            }
                        }
                    }
                    _ => {
                        if !cached.is_empty() {
                            let b = cached.swap_remove((op / 8) as usize % cached.len());
                            if op % 2 == 0 {
                                assert!(a.retain_from_zero(b));
                                live.push(b);
                            } else {
                                a.free_cached(b);
                            }
                        }
                    }
                }
                if !a.check_invariants() {
                    return Err(format!("invariants broken after op {op}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ prefix keys

/// insert-then-match covers the whole chain; a diverging suffix matches
/// exactly the shared prefix blocks.
#[test]
fn prop_prefix_chain_consistency() {
    forall(
        "prefix-chain",
        200,
        |rng, size| {
            let shared = gen::vec_u32(rng, Size(size.0 * 4), 1000);
            let a_suffix = gen::vec_u32(rng, size, 1000);
            let b_suffix = gen::vec_u32(rng, size, 1000);
            (shared, a_suffix, b_suffix)
        },
        |(shared, a_suffix, b_suffix)| {
            let bs = 16;
            let mut pa = shared.clone();
            pa.extend(a_suffix);
            let mut pb = shared.clone();
            pb.extend(b_suffix);
            let ka = prompt_block_keys(&pa, bs);
            let kb = prompt_block_keys(&pb, bs);
            let mut cache = PrefixCache::new();
            let mut alloc = BlockAllocator::new(4096, bs);
            let blocks: Vec<u32> = ka.iter().map(|_| alloc.alloc().unwrap()).collect();
            for (k, b) in ka.iter().zip(&blocks) {
                cache.insert(*k, *b);
            }
            if cache.match_len(&ka) != ka.len() {
                return Err("full self-match failed".into());
            }
            let matched = cache.match_len(&kb);
            let shared_blocks = shared.len() / bs;
            if matched < shared_blocks.min(kb.len()) {
                return Err(format!(
                    "matched {matched} < shared full blocks {shared_blocks}"
                ));
            }
            // Matched region must never exceed the divergence point unless
            // the suffixes happen to agree block-wise (compare real keys).
            for i in 0..matched.min(ka.len()).min(kb.len()) {
                if ka[i] != kb[i] {
                    return Err(format!("match claims equality at diverging block {i}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------- eviction

/// All eviction policies: every insert is eventually evictable exactly
/// once; len is consistent; no key is ever returned twice.
#[test]
fn prop_eviction_conservation() {
    for kind in [EvictionKind::Lru, EvictionKind::Fifo, EvictionKind::S3Fifo] {
        forall(
            "eviction-conservation",
            100,
            |rng, size| {
                let ops: Vec<(u8, u64)> = (0..size.0 * 2)
                    .map(|_| (rng.below(3) as u8, rng.below(size.0 as u64 + 1)))
                    .collect();
                ops
            },
            |ops| {
                let mut p = kind.build();
                let mut resident = std::collections::BTreeSet::new();
                for &(op, key) in ops {
                    match op {
                        0 => {
                            if resident.insert(key) {
                                p.on_insert(key);
                            }
                        }
                        1 => {
                            p.on_access(key);
                        }
                        _ => {
                            if let Some(v) = p.evict() {
                                if !resident.remove(&v) {
                                    return Err(format!("{kind:?} evicted non-resident {v}"));
                                }
                            } else if !resident.is_empty() {
                                return Err(format!(
                                    "{kind:?} refused to evict with {} resident",
                                    resident.len()
                                ));
                            }
                        }
                    }
                    if p.len() != resident.len() {
                        return Err(format!(
                            "{kind:?} len {} != model {}",
                            p.len(),
                            resident.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

// --------------------------------------------------------------- router

/// The router always returns a ready pod when one exists, never an
/// un-ready one, and is deterministic per seed.
#[test]
fn prop_router_totality() {
    forall(
        "router-totality",
        300,
        |rng, _| {
            let n = 1 + gen::usize_up_to(rng, 12);
            let pods: Vec<(bool, usize, f64, f64)> = (0..n)
                .map(|_| {
                    (
                        rng.chance(0.8),
                        gen::usize_up_to(rng, 50),
                        rng.uniform(0.0, 1.0),
                        rng.uniform(0.0, 500_000.0),
                    )
                })
                .collect();
            // Sweep every named preset, including the ClusterView trio.
            let policy_idx = gen::usize_up_to(rng, Policy::extended().len());
            (pods, policy_idx, rng.next_u64())
        },
        |(pods, policy_idx, seed)| {
            let snaps: Vec<PodSnapshot> = pods
                .iter()
                .enumerate()
                .map(|(i, &(ready, load, kv, lat))| PodSnapshot {
                    pod: i,
                    ready,
                    stats: aibrix::engine::EngineStats {
                        waiting: load,
                        running: load / 2,
                        kv_utilization: kv,
                        tokens_per_s: lat / 100.0,
                        avg_latency_us: lat,
                        prefix_hit_rate: kv,
                        ..Default::default()
                    },
                    prefix_match_blocks: load % 11,
                    prompt_blocks: 10,
                    pool_blocks_local: load % 5,
                    pool_blocks_total: load % 11,
                    session_match: load % 4 == 0,
                    slo_headroom: kv,
                    resident_adapters: vec![],
                    health: Default::default(),
                })
                .collect();
            let policy = Policy::extended()[*policy_idx];
            let req = Request {
                id: 0,
                session: 0,
                tokens: vec![1; 160],
                output_len: 4,
                arrival: 0,
                model: "m".into(),
                adapter: None,
                user: 0,
                shared_prefix_len: 0,
                end_session: false,
                deadline: None,
                tier: Default::default(),
            };
            let pick1 = Router::new(policy, *seed).select(&req, &snaps);
            let pick2 = Router::new(policy, *seed).select(&req, &snaps);
            if pick1 != pick2 {
                return Err("non-deterministic".into());
            }
            let any_ready = snaps.iter().any(|p| p.ready);
            match pick1 {
                Some(i) => {
                    let p = snaps.iter().find(|p| p.pod == i).unwrap();
                    if !p.ready {
                        return Err(format!("picked un-ready pod {i}"));
                    }
                    Ok(())
                }
                None if !any_ready => Ok(()),
                None => Err("returned None with ready pods".into()),
            }
        },
    );
}

// ------------------------------------------------------------ fair queue

/// Conservation: every pushed request pops exactly once, regardless of
/// interleaving; within a tenant, order is FIFO.
#[test]
fn prop_fair_queue_conservation() {
    forall(
        "fairqueue-conservation",
        200,
        |rng, size| {
            let reqs: Vec<(u32, usize)> = (0..size.0 * 2)
                .map(|_| (rng.below(5) as u32, 1 + gen::usize_up_to(rng, 2000)))
                .collect();
            (reqs, rng.uniform(1.0, 1000.0))
        },
        |(reqs, quantum)| {
            let mut q = FairQueue::new(*quantum);
            for (i, &(user, tokens)) in reqs.iter().enumerate() {
                q.push(Request {
                    id: i as u64,
                    session: 0,
                    tokens: vec![0; tokens],
                    output_len: 0,
                    arrival: 0,
                    model: "m".into(),
                    adapter: None,
                    user,
                    shared_prefix_len: 0,
                    end_session: false,
                    deadline: None,
                    tier: Default::default(),
                });
            }
            let mut seen = std::collections::BTreeSet::new();
            let mut last_per_user: std::collections::BTreeMap<u32, u64> = Default::default();
            while let Some(r) = q.pop() {
                if !seen.insert(r.id) {
                    return Err(format!("request {} popped twice", r.id));
                }
                if let Some(&last) = last_per_user.get(&r.user) {
                    if r.id < last {
                        return Err(format!("tenant {} order violated", r.user));
                    }
                }
                last_per_user.insert(r.user, r.id);
            }
            if seen.len() != reqs.len() {
                return Err(format!("{} popped of {}", seen.len(), reqs.len()));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------- histogram

/// Histogram percentiles stay within the log-bucket relative-error bound of
/// exact percentiles.
#[test]
fn prop_histogram_accuracy() {
    forall(
        "histogram-accuracy",
        100,
        |rng, size| {
            let n = 100 + size.0 * 10;
            (0..n)
                .map(|_| (rng.f64_open() * 1e7) as u64 + 1)
                .collect::<Vec<u64>>()
        },
        |samples| {
            let mut h = Histogram::new();
            for &s in samples {
                h.record(s);
            }
            let as_f: Vec<f64> = samples.iter().map(|&s| s as f64).collect();
            for p in [50.0, 90.0, 99.0] {
                let exact = percentile(&as_f, p);
                let approx = h.percentile(p) as f64;
                // Log-bucket low-edge estimate: within ~7% below, never
                // above by more than one bucket.
                if approx > exact * 1.07 + 1.0 || approx < exact * 0.86 - 1.0 {
                    return Err(format!("p{p}: approx {approx} vs exact {exact}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- engine

/// Any workload the engine admits completes: no stuck sequences, no leaked
/// blocks, allocator invariants hold throughout.
#[test]
fn prop_engine_liveness_and_no_leaks() {
    forall(
        "engine-liveness",
        40,
        |rng, size| {
            let n = 1 + gen::usize_up_to(rng, size.0 / 2 + 1);
            let reqs: Vec<(usize, usize)> = (0..n)
                .map(|_| (1 + gen::usize_up_to(rng, 3000), 1 + gen::usize_up_to(rng, 40)))
                .collect();
            let chunked = rng.chance(0.5);
            let prefix = rng.chance(0.5);
            (reqs, chunked, prefix)
        },
        |(reqs, chunked, prefix)| {
            let mut cfg = EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
            cfg.chunked_prefill = *chunked;
            if *chunked {
                cfg.max_batched_tokens = 512;
            }
            cfg.prefix_caching = *prefix;
            let mut e = EngineSim::new(0, 0, cfg);
            for (i, &(prompt, out)) in reqs.iter().enumerate() {
                e.enqueue(Request {
                    id: i as u64,
                    session: 0,
                    tokens: vec![(i % 100) as u32; prompt],
                    output_len: out,
                    arrival: 0,
                    model: "m".into(),
                    adapter: None,
                    user: 0,
                    shared_prefix_len: 0,
                    end_session: false,
                    deadline: None,
                    tier: Default::default(),
                });
            }
            let mut now = 0;
            let mut steps = 0;
            while e.has_work() {
                match e.step(now, None) {
                    Some(dt) => now += dt,
                    None => break,
                }
                if !e.check_invariants() {
                    return Err("allocator invariants broken mid-run".into());
                }
                steps += 1;
                if steps > 200_000 {
                    return Err("engine did not drain (livelock?)".into());
                }
            }
            if e.completions.len() != reqs.len() {
                return Err(format!(
                    "completed {} of {}",
                    e.completions.len(),
                    reqs.len()
                ));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------------- sim

/// Event delivery is globally time-ordered and ties respect insertion
/// order.
#[test]
fn prop_sim_total_order() {
    forall(
        "sim-order",
        200,
        |rng, size| {
            (0..size.0 * 2)
                .map(|_| rng.below(1000))
                .collect::<Vec<u64>>()
        },
        |times| {
            let mut sim = Simulator::new();
            for (i, &t) in times.iter().enumerate() {
                sim.schedule_at(t, i);
            }
            let mut last_t = 0;
            let mut last_seq_at_t: Option<usize> = None;
            while let Some((t, i)) = sim.next_event() {
                if t < last_t {
                    return Err("time went backwards".into());
                }
                if t == last_t {
                    if let Some(prev) = last_seq_at_t {
                        if times[prev] == times[i] && prev > i {
                            return Err("tie broke insertion order".into());
                        }
                    }
                }
                last_t = t;
                last_seq_at_t = Some(i);
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------------ json

/// Serializer/parser round-trip is the identity on arbitrary JSON trees.
#[test]
fn prop_json_round_trip() {
    fn arbitrary(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| arbitrary(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), arbitrary(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        "json-round-trip",
        300,
        |rng, _| arbitrary(rng, 3),
        |v| {
            let text = v.to_string();
            let back = parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
            if &back != v {
                return Err(format!("round trip changed value: {v} -> {back}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- scalers

/// Every scaler's desired replica count stays within [min, max] under
/// arbitrary metric streams.
#[test]
fn prop_scalers_bounded() {
    use aibrix::autoscaler::{Apa, Hpa, Kpa, Scaler};
    forall(
        "scalers-bounded",
        100,
        |rng, size| {
            (0..size.0 * 2)
                .map(|_| rng.uniform(0.0, 500.0))
                .collect::<Vec<f64>>()
        },
        |loads| {
            let (min, max) = (2usize, 9usize);
            let mut scalers: Vec<Box<dyn Scaler>> = vec![
                Box::new(Hpa::new(8.0, min, max)),
                Box::new(Kpa::new(8.0, min, max)),
                Box::new(Apa::new(8.0, min, max)),
            ];
            for s in scalers.iter_mut() {
                let mut current = 4;
                for (i, &l) in loads.iter().enumerate() {
                    let now = i as u64 * 1_000_000;
                    s.observe(now, l);
                    let d = s.desired(now, current);
                    if current >= min && current <= max && (d < min || d > max) {
                        return Err(format!("{} returned {d} outside [{min},{max}]", s.name()));
                    }
                    current = d.clamp(min, max);
                }
            }
            Ok(())
        },
    );
}

// -------------------------------------------------------------- tokenizer

/// Prefix stability over arbitrary ASCII strings.
#[test]
fn prop_tokenizer_prefix_stable() {
    use aibrix::tokenizer::Tokenizer;
    forall(
        "tokenizer-prefix",
        200,
        |rng, size| {
            let a: String = (0..rng.below(size.0 as u64 + 1))
                .map(|_| (rng.below(94) as u8 + 32) as char)
                .collect();
            let b: String = (0..rng.below(size.0 as u64 + 1))
                .map(|_| (rng.below(94) as u8 + 32) as char)
                .collect();
            (a, b)
        },
        |(a, b)| {
            let t = Tokenizer::new(512);
            let ta = t.encode(a);
            let tab = t.encode(&format!("{a}{b}"));
            if tab.len() < ta.len() || tab[..ta.len()] != ta[..] {
                return Err("prefix stability violated".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------- KV pool

/// Random interleaved insert/lookup/prefetch sequences over 1–4 shards,
/// dedup on/off, int8 quantization on/off, a cold spill tier on/off,
/// shard-less writers, and a mix of metadata-only and data-bearing
/// inserts: `check_invariants()` (index/policy/byte accounting agreement,
/// per-shard capacity, data tier ⊆ index, cold-tier byte accounting, and
/// RAM∩cold disjointness — a promotion must move a block, never duplicate
/// it) holds after *every* operation. This property catches both
/// historical pool accounting bugs — the dedup-off re-insert that ran the
/// make-room loop before freeing its own old copy, and once-per-call
/// placement hot-spotting a shard-less writer's multi-block write-back —
/// and pins the tiered-cache extension: spills, promotions, prefetches,
/// quantized inserts, and shard drops may interleave in any order without
/// the two tiers ever disagreeing.
#[test]
fn prop_kv_pool_accounting_invariants() {
    use aibrix::engine::ExternalKv;
    use aibrix::kvcache::blocks::{KvBlockData, KvBlockShape};
    use aibrix::kvcache::{DistKvPool, KvPoolConfig};
    use std::sync::Arc;

    const SHAPE: KvBlockShape = KvBlockShape { n_layers: 1, block_tokens: 16, d_model: 4 };

    #[derive(Debug)]
    struct Scenario {
        shards: usize,
        dedup: bool,
        quant: bool,
        /// Cold-tier capacity in bytes (0 = off). Sized to a handful of
        /// encoded blocks so the FIFO cold-eviction path churns too.
        cold_bytes: u64,
        /// (op kind, writer/reader node, chain start key, chain length)
        ops: Vec<(u8, u64, u64, usize)>,
    }

    forall(
        "kv-pool-invariants",
        150,
        |rng, size| Scenario {
            shards: 1 + rng.below(4) as usize,
            dedup: rng.below(2) == 0,
            quant: rng.below(2) == 0,
            cold_bytes: [0, 2 * 1024, 8 * 1024][rng.below(3) as usize],
            ops: (0..size.0.max(8))
                .map(|_| {
                    (
                        // Rare shard drops (kind 3) interleave with the
                        // insert/lookup/prefetch churn: losing a node
                        // mid-stream must keep both tiers consistent.
                        if rng.chance(0.08) {
                            3
                        } else {
                            [0, 1, 2, 4][rng.below(4) as usize]
                        },
                        rng.below(6),                // nodes 4.. have no shard
                        1 + rng.below(24),           // small key space => collisions
                        1 + rng.below(6) as usize,   // blocks per op
                    )
                })
                .collect(),
        },
        |sc| {
            // Tiny shards (3 blocks each) force constant eviction churn;
            // with quant on the same bytes hold 4x the blocks, so the
            // charged-bytes accounting is exercised at both densities.
            let nodes: Vec<(u64, u64)> = (0..sc.shards as u64).map(|i| (i, 3 * 1024)).collect();
            let mut cfg = KvPoolConfig::new(nodes, 64, 16); // block = 1024 bytes
            cfg.dedup = sc.dedup;
            cfg.quant = sc.quant;
            cfg.cold_bytes = sc.cold_bytes;
            let mut pool = DistKvPool::new(cfg);
            pool.set_shape(SHAPE).map_err(|e| e.to_string())?;
            // Varied values so quantized blocks carry non-trivial scales.
            let data = Arc::new(KvBlockData {
                k: (0..SHAPE.floats_per_side()).map(|i| (i % 7) as f32 - 3.0).collect(),
                v: (0..SHAPE.floats_per_side()).map(|i| (i % 5) as f32 * 0.5).collect(),
            });
            for (step, &(kind, node, start, len)) in sc.ops.iter().enumerate() {
                // Advancing clock straddles the 50ms visibility delay.
                let now = step as u64 * 9_000;
                let keys: Vec<u64> = (start..start + len as u64).collect();
                match kind {
                    0 => pool.insert(now, node, &keys, 16),
                    1 => {
                        let items: Vec<(u64, Arc<KvBlockData>)> =
                            keys.iter().map(|&k| (k, Arc::clone(&data))).collect();
                        pool.insert_blocks(now, node, &items).map_err(|e| e.to_string())?;
                    }
                    2 => {
                        let (fetch, blocks) = pool.lookup_blocks(now, node, &keys);
                        if blocks.len() > fetch.blocks_hit {
                            return Err(format!(
                                "op {step}: {} data blocks for {} hits",
                                blocks.len(),
                                fetch.blocks_hit
                            ));
                        }
                    }
                    4 => {
                        // Prefetch promotes cold blocks / warms RAM ones;
                        // its counters must stay internally consistent.
                        pool.prefetch(now, node, &keys);
                        let s = &pool.stats;
                        if s.prefetch_hits > s.prefetch_issued {
                            return Err(format!(
                                "op {step}: {} prefetch hits for {} issued",
                                s.prefetch_hits, s.prefetch_issued
                            ));
                        }
                    }
                    _ => {
                        // Chaos: drop the node's shard (no-op for nodes
                        // that never had one, or already-dropped ones).
                        // Cold-resident blocks survive the drop.
                        let had = pool.has_shard(node);
                        let dropped = pool.drop_shard(node);
                        if !had && dropped > 0 {
                            return Err(format!(
                                "op {step}: dropped {dropped} blocks from absent shard {node}"
                            ));
                        }
                    }
                }
                if !pool.check_invariants() {
                    return Err(format!(
                        "op {step} ({kind} node={node} keys={start}..+{len}) broke invariants"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Int8-resident attention stays within its analytic error bound vs the
/// full-f32 kernel (the `attend_one_i8` contract, PR 4 `gemm_i8` style):
/// per-score |Δs| ≤ (k_scale/2)·‖q‖₁/√hd, softmax weights move by at most
/// e^{2Δmax}−1 in total variation, so per output element
/// |Δout| ≤ max(v_scale)/2 + (e^{2Δmax}−1)·(max|v| + max(v_scale)/2),
/// plus a small float-accumulation slack. Random shapes, random mixed
/// int8/f32 split points (qlen 0 = pure f32 passthrough, qlen = kv_len =
/// fully int8-resident), every head checked.
#[test]
fn prop_attend_one_i8_error_within_analytic_bound() {
    use aibrix::runtime::kernels::{attend_one, attend_one_i8, quantize_rows};

    #[derive(Debug)]
    struct Case {
        n_heads: usize,
        hd: usize,
        kv_len: usize,
        /// Positions `0..qlen` are int8-resident, the rest stay f32.
        qlen: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    }

    forall(
        "attend-one-i8-bound",
        200,
        |rng, _size| {
            let n_heads = 1 + rng.below(2) as usize;
            let hd = if rng.below(2) == 0 { 4 } else { 8 };
            let kv_len = 1 + rng.below(12) as usize;
            let qlen = rng.below(kv_len as u64 + 1) as usize;
            let stride = n_heads * hd;
            let q: Vec<f32> = (0..hd).map(|_| rng.below(4001) as f32 / 1000.0 - 2.0).collect();
            let k: Vec<f32> =
                (0..kv_len * stride).map(|_| rng.below(6001) as f32 / 1000.0 - 3.0).collect();
            let v: Vec<f32> =
                (0..kv_len * stride).map(|_| rng.below(6001) as f32 / 1000.0 - 3.0).collect();
            Case { n_heads, hd, kv_len, qlen, q, k, v }
        },
        |c| {
            let stride = c.n_heads * c.hd;
            let kq = quantize_rows(&c.k[..c.qlen * stride], c.qlen, stride);
            let vq = quantize_rows(&c.v[..c.qlen * stride], c.qlen, stride);
            // Analytic pieces of the bound.
            let q_l1: f32 = c.q.iter().map(|x| x.abs()).sum();
            let inv_sqrt = 1.0 / (c.hd as f32).sqrt();
            let d_max =
                kq.scales.iter().map(|s| 0.5 * s * q_l1 * inv_sqrt).fold(0.0f32, f32::max);
            let max_vs = vq.scales.iter().fold(0.0f32, |a, &s| a.max(s));
            let max_abs_v = c.v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let bound = (0.5 * max_vs
                + ((2.0 * d_max).exp() - 1.0) * (max_abs_v + 0.5 * max_vs))
                * 1.01
                + 1e-4;
            let mut scores = Vec::new();
            let mut out_ref = vec![0.0f32; c.hd];
            let mut out_q = vec![0.0f32; c.hd];
            for head in 0..c.n_heads {
                attend_one(&c.q, &c.k, &c.v, c.kv_len, head, c.n_heads, &mut scores, &mut out_ref);
                attend_one_i8(
                    &c.q, &kq.data, &kq.scales, &vq.data, &vq.scales, c.qlen, &c.k, &c.v,
                    c.kv_len, head, c.n_heads, &mut scores, &mut out_q,
                );
                for d in 0..c.hd {
                    let err = (out_ref[d] - out_q[d]).abs();
                    if !err.is_finite() || err > bound {
                        return Err(format!(
                            "head {head} dim {d}: err {err} > bound {bound} (Δmax {d_max})"
                        ));
                    }
                }
                // qlen == 0 must be an exact f32 passthrough, bit for bit.
                if c.qlen == 0 && out_ref != out_q {
                    return Err("qlen=0 must be bit-identical to attend_one".into());
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ chaos plane

/// Request conservation under *any* seeded fault schedule: whatever mix of
/// replica deaths, stragglers and shard losses fires, every request the
/// workload emits ends as exactly one completion or one typed rejection —
/// ids partition perfectly, nothing is silently lost, and the run is
/// reproducible from its seed.
#[test]
fn prop_chaos_request_conservation() {
    use aibrix::chaos::ChaosSchedule;
    use aibrix::engine::ModelSpec;
    use aibrix::harness::{run, HarnessConfig};
    use aibrix::kvcache::KvPoolConfig;
    use aibrix::sim::SimTime;
    use aibrix::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload, Workload};
    use std::collections::HashSet;

    /// Randomly flags requests as a session's final turn: `end_session`
    /// frees the sticky-affinity slot on both the dispatch and the
    /// post-fault re-dispatch paths, and conservation must not care.
    struct EndSessionChaos {
        inner: BirdSqlWorkload,
        rng: Rng,
    }

    impl Workload for EndSessionChaos {
        fn next(&mut self, now: SimTime) -> Option<Request> {
            let mut r = self.inner.next(now)?;
            if r.session != 0 && self.rng.chance(0.3) {
                r.end_session = true;
            }
            Some(r)
        }
    }

    forall(
        "chaos-request-conservation",
        12, // each case is a full harness run — keep the count tight
        |rng, _| {
            (
                rng.next_u64(),                // chaos + harness seed
                2 + rng.below(3) as usize,     // pods
                20 + rng.below(40) as usize,   // requests
                rng.below(2) == 0,             // distributed pool on/off
            )
        },
        |&(seed, pods, n, pool_on)| {
            let kv_bytes = ModelSpec::deepseek_coder_7b().kv_bytes_per_token();
            let nodes: Vec<u64> = (0..pods as u64).collect();
            let cfg = HarnessConfig {
                engines: (0..pods)
                    .map(|i| {
                        let mut ec =
                            EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
                        ec.prefix_caching = true;
                        (ec, i as u64)
                    })
                    .collect(),
                policy: Policy::LeastRequest,
                arrival: ArrivalProcess::Poisson { rate: 60.0 },
                kv_pool: if pool_on {
                    Some(KvPoolConfig::new(
                        nodes.iter().map(|&i| (i, 8u64 << 30)).collect(),
                        kv_bytes,
                        16,
                    ))
                } else {
                    None
                },
                seed,
                deadline: 0,
                closed_loop_clients: 0,
                view: Default::default(),
                chaos: Some(ChaosSchedule::from_seed(seed, pods, &nodes, 2_000_000)),
                recovery: Default::default(),
                admission: None,
            };
            let mut w = EndSessionChaos {
                inner: BirdSqlWorkload::new(BirdSqlConfig {
                    n_requests: n,
                    n_schemas: 4,
                    schema_tokens_mean: 300,
                    question_tokens_mean: 80,
                    ..Default::default()
                }),
                rng: Rng::new(seed ^ 0xE5D),
            };
            let r = run(cfg, &mut w);
            if r.completions.len() + r.rejections.len() != n {
                return Err(format!(
                    "lost requests: {} completed + {} rejected != {n}",
                    r.completions.len(),
                    r.rejections.len()
                ));
            }
            // Each id gets exactly one terminal outcome — a request that
            // both completed and was rejected (or did either twice) is as
            // broken as a lost one.
            let mut seen = HashSet::new();
            for id in r
                .completions
                .iter()
                .map(|c| c.req_id)
                .chain(r.rejections.iter().map(|&(id, _)| id))
            {
                if !seen.insert(id) {
                    return Err(format!("request {id} has two terminal outcomes"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------- continuous batching

/// Scheduling is invisible in the outputs: whatever chunk budget, KV
/// budget (tight enough to preempt) and arrival interleaving the
/// continuous-batching scheduler runs under, every request's generated
/// tokens are bit-identical to the lockstep engine serving the same
/// trace (DESIGN.md bit-exactness contract, ISSUE 8).
#[test]
fn prop_sched_engine_matches_lockstep() {
    use aibrix::engine::real::{RealEngine, RealRequest};
    use aibrix::engine::{SchedConfig, SchedEngine};
    use aibrix::runtime::{ModelCfg, SyntheticSpec, TinyLmRuntime};

    // Tiny model: lockstep window 40, decode budget 48-40 = 8. Prompts
    // and decode targets stay under those caps so the lockstep engine
    // never truncates and per-request outputs are comparable.
    fn spec() -> SyntheticSpec {
        SyntheticSpec {
            cfg: ModelCfg {
                vocab: 32,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                head_dim: 8,
                max_seq: 48,
                page_size: 8,
            },
            d_ff: 32,
            prefill: vec![(1, 40), (2, 40)],
            decode: vec![1, 2],
            seed: 5,
        }
    }

    forall(
        "sched-vs-lockstep",
        20, // each case runs two real engines — keep the count tight
        |rng, _| {
            let n = 1 + gen::usize_up_to(rng, 5);
            let reqs: Vec<(usize, usize)> = (0..n)
                .map(|_| (1 + gen::usize_up_to(rng, 39), 1 + gen::usize_up_to(rng, 7)))
                .collect();
            let chunk = 1 + gen::usize_up_to(rng, 47);
            // Down to the clamp floor (one row's worth): tight cases
            // exercise preemption + lossless re-prefill.
            let budget = 48 + gen::usize_up_to(rng, 96);
            (reqs, chunk, budget)
        },
        |(reqs, chunk, budget)| {
            let mk = |i: usize, &(prompt, max_new): &(usize, usize)| RealRequest {
                id: i as u64,
                tokens: (0..prompt).map(|s| ((i * 31 + s * 7 + 3) % 32) as u32).collect(),
                max_new_tokens: max_new,
                ..Default::default()
            };
            let mut lock = RealEngine::from_runtime(TinyLmRuntime::synthetic(&spec()), None)
                .map_err(|e| e.to_string())?;
            for (i, r) in reqs.iter().enumerate() {
                lock.enqueue(mk(i, r));
            }
            lock.run_to_drain().map_err(|e| e.to_string())?;

            let rt = TinyLmRuntime::synthetic(&spec());
            let cfg = SchedConfig { chunk_tokens: *chunk, kv_token_budget: *budget };
            let mut sched =
                SchedEngine::with_config(rt, None, cfg).map_err(|e| e.to_string())?;
            for (i, r) in reqs.iter().enumerate() {
                sched.enqueue(mk(i, r));
            }
            sched.run_to_drain().map_err(|e| e.to_string())?;

            if sched.completions.len() != reqs.len() {
                return Err(format!(
                    "scheduler completed {} of {}",
                    sched.completions.len(),
                    reqs.len()
                ));
            }
            let by_id = |cs: &[aibrix::engine::real::RealCompletion]| {
                let mut v: Vec<(u64, Vec<u32>)> =
                    cs.iter().map(|c| (c.id, c.generated.clone())).collect();
                v.sort();
                v
            };
            if by_id(&lock.completions) != by_id(&sched.completions) {
                return Err(format!(
                    "outputs diverged (chunk={chunk}, budget={budget})"
                ));
            }
            Ok(())
        },
    );
}

/// Conservation through an engine fault, scheduler edition: fail the
/// engine at an arbitrary iteration and every enqueued request is either
/// already completed or comes back out of `fail_and_drain` (waiting queue
/// AND in-flight slots) exactly once — and a healthy peer re-serving the
/// drained requests reproduces the fault-free outputs bit for bit.
#[test]
fn prop_sched_chaos_conservation() {
    use aibrix::engine::real::{RealEngine, RealRequest};
    use aibrix::engine::SchedEngine;
    use aibrix::runtime::{ModelCfg, SyntheticSpec, TinyLmRuntime};
    use std::collections::BTreeMap;

    fn spec() -> SyntheticSpec {
        SyntheticSpec {
            cfg: ModelCfg {
                vocab: 32,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                head_dim: 8,
                max_seq: 48,
                page_size: 8,
            },
            d_ff: 32,
            prefill: vec![(1, 40), (2, 40)],
            decode: vec![1, 2],
            seed: 5,
        }
    }

    forall(
        "sched-chaos-conservation",
        15,
        |rng, _| {
            let n = 2 + gen::usize_up_to(rng, 5);
            let reqs: Vec<(usize, usize)> = (0..n)
                .map(|_| (1 + gen::usize_up_to(rng, 39), 1 + gen::usize_up_to(rng, 7)))
                .collect();
            let fault_tick = gen::usize_up_to(rng, 20);
            (reqs, fault_tick)
        },
        |(reqs, fault_tick)| {
            let mk = |i: usize, &(prompt, max_new): &(usize, usize)| RealRequest {
                id: i as u64,
                tokens: (0..prompt).map(|s| ((i * 31 + s * 7 + 3) % 32) as u32).collect(),
                max_new_tokens: max_new,
                ..Default::default()
            };
            // Fault-free reference (lockstep keeps the two engine cores
            // honest against each other here too).
            let mut reference =
                RealEngine::from_runtime(TinyLmRuntime::synthetic(&spec()), None)
                    .map_err(|e| e.to_string())?;
            for (i, r) in reqs.iter().enumerate() {
                reference.enqueue(mk(i, r));
            }
            reference.run_to_drain().map_err(|e| e.to_string())?;
            let want: BTreeMap<u64, Vec<u32>> = reference
                .completions
                .iter()
                .map(|c| (c.id, c.generated.clone()))
                .collect();

            let mut victim =
                SchedEngine::from_runtime(TinyLmRuntime::synthetic(&spec()), None)
                    .map_err(|e| e.to_string())?;
            for (i, r) in reqs.iter().enumerate() {
                victim.enqueue(mk(i, r));
            }
            for _ in 0..*fault_tick {
                if victim.pending() == 0 {
                    break;
                }
                victim.tick().map_err(|e| e.to_string())?;
            }
            let drained = victim.fail_and_drain();
            if victim.completions.len() + drained.len() != reqs.len() {
                return Err(format!(
                    "leak at tick {fault_tick}: {} done + {} drained != {}",
                    victim.completions.len(),
                    drained.len(),
                    reqs.len()
                ));
            }

            let mut peer =
                SchedEngine::from_runtime(TinyLmRuntime::synthetic(&spec()), None)
                    .map_err(|e| e.to_string())?;
            for r in drained {
                peer.enqueue(r);
            }
            peer.run_to_drain().map_err(|e| e.to_string())?;
            let mut got: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
            for c in victim.completions.iter().chain(peer.completions.iter()) {
                if got.insert(c.id, c.generated.clone()).is_some() {
                    return Err(format!("request {} completed twice", c.id));
                }
            }
            if got != want {
                return Err(format!(
                    "recovered outputs diverge from fault-free run at tick {fault_tick}"
                ));
            }
            Ok(())
        },
    );
}

/// Detection latency: a replica death is diagnosed (fatal XID), drained and
/// cordoned within a small multiple of the diagnostics sweep interval,
/// wherever in the run it strikes — and still loses nothing.
#[test]
fn prop_faults_detected_and_cordoned() {
    use aibrix::chaos::{ChaosEvent, ChaosFault, ChaosSchedule, RecoveryPolicy};
    use aibrix::engine::ModelSpec;
    use aibrix::harness::{run, HarnessConfig};
    use aibrix::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload};

    forall(
        "chaos-detect-to-cordon",
        10,
        |rng, _| {
            (
                rng.next_u64(),
                200_000 + rng.below(1_300_000), // fault time, well inside the run
                rng.below(3) as usize,          // victim pod
            )
        },
        |&(seed, at, victim)| {
            let cfg = HarnessConfig {
                engines: (0..3)
                    .map(|i| {
                        let mut ec =
                            EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
                        ec.prefix_caching = true;
                        (ec, i as u64)
                    })
                    .collect(),
                policy: Policy::LeastRequest,
                arrival: ArrivalProcess::Poisson { rate: 60.0 },
                kv_pool: None,
                seed,
                deadline: 0,
                closed_loop_clients: 0,
                view: Default::default(),
                chaos: Some(ChaosSchedule::new(vec![ChaosEvent {
                    at,
                    fault: ChaosFault::ReplicaDeath { pod: victim },
                }])),
                recovery: Default::default(),
                admission: None,
            };
            let mut w = BirdSqlWorkload::new(BirdSqlConfig {
                n_requests: 120,
                n_schemas: 4,
                schema_tokens_mean: 300,
                question_tokens_mean: 80,
                ..Default::default()
            });
            let r = run(cfg, &mut w);
            if r.completions.len() + r.rejections.len() != 120 {
                return Err(format!(
                    "lost requests: {} + {} != 120",
                    r.completions.len(),
                    r.rejections.len()
                ));
            }
            let d = r
                .detect_to_cordon_us
                .ok_or_else(|| format!("death at {at}µs never cordoned pod {victim}"))?;
            let bound = 3 * RecoveryPolicy::default().sweep_interval_us;
            if d > bound {
                return Err(format!("detect-to-cordon {d}µs exceeds {bound}µs"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------- overload protection

/// ISSUE 9 anti-inversion invariant, checked at a single decision
/// instant: whenever the admission controller admits a request, it must
/// also admit any *higher*-priority request carrying an equal-or-later
/// deadline against the very same fleet snapshots. The feasibility floor
/// (predictive deadline sheds only engage at/above the next-lower tier's
/// shed threshold) exists precisely to make this a theorem — without it,
/// a queue-ahead estimate could shed an Interactive deadline while Batch
/// sailed through.
#[test]
fn prop_admission_no_priority_inversion() {
    use aibrix::engine::EngineStats;
    use aibrix::gateway::{AdmissionConfig, AdmissionController};
    use aibrix::workload::Tier;

    fn mk(tier: Tier, deadline: Option<u64>) -> Request {
        Request {
            id: 0,
            session: 0,
            tokens: vec![1; 64],
            output_len: 8,
            arrival: 0,
            model: "m".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline,
            tier,
        }
    }

    forall(
        "admission-no-priority-inversion",
        500,
        |rng, _| {
            let n = 1 + gen::usize_up_to(rng, 4);
            let pods: Vec<(f64, usize, f64, f64)> = (0..n)
                .map(|_| {
                    (
                        rng.uniform(0.0, 1.0),     // pressure
                        gen::usize_up_to(rng, 60), // waiting
                        rng.uniform(0.0, 8_000.0), // tokens/s (0 = fallback)
                        rng.uniform(0.0, 1.0),     // kv utilization
                    )
                })
                .collect();
            let now = rng.below(1_000_000);
            let lo_deadline =
                if rng.chance(0.3) { None } else { Some(now + 1 + rng.below(2_000_000)) };
            let extra = rng.below(1_000_000);
            (pods, now, lo_deadline, extra)
        },
        |&(ref pods, now, lo_deadline, extra)| {
            let snaps: Vec<PodSnapshot> = pods
                .iter()
                .enumerate()
                .map(|(i, &(pressure, waiting, tokens_per_s, kv_utilization))| PodSnapshot {
                    pod: i,
                    stats: EngineStats {
                        pressure,
                        waiting,
                        running: waiting / 3,
                        tokens_per_s,
                        kv_utilization,
                        ..Default::default()
                    },
                    ..Default::default()
                })
                .collect();
            let mut ac = AdmissionController::new(AdmissionConfig::default());
            // Every higher/lower tier pairing; the higher-priority request
            // never carries the *tighter* deadline.
            for (hi, lo) in [
                (Tier::Interactive, Tier::Standard),
                (Tier::Interactive, Tier::Batch),
                (Tier::Standard, Tier::Batch),
            ] {
                let hi_deadline = lo_deadline.map(|d| d + extra);
                let lo_ok = ac.evaluate(now, &mk(lo, lo_deadline), &snaps).is_ok();
                let hi_ok = ac.evaluate(now, &mk(hi, hi_deadline), &snaps).is_ok();
                if lo_ok && !hi_ok {
                    return Err(format!(
                        "priority inversion: {lo:?} (deadline {lo_deadline:?}) admitted \
                         while {hi:?} (deadline {hi_deadline:?}) was shed"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE 9 end-to-end overload conservation: under a random overload
/// factor, tier mix, deadline budget and (optionally) a chaos schedule,
/// every emitted request terminates as exactly one completion or one
/// typed rejection with ids partitioning perfectly; the admission
/// counters' pressure lane reconciles against the rejection ledger via
/// the workload's deterministic id→tier map (exactly without chaos —
/// post-fault retries re-run admission, so under chaos the terminal
/// ledger is a lower bound); the unprotected leg conserves too with
/// untouched counters; and the whole run replays bit-identically from
/// its seed. Termination of the protected run doubles as the observable
/// form of "brownout always recovers": a brownout that failed to exit
/// would strand admitted work and break conservation.
#[test]
fn prop_overload_conservation() {
    use aibrix::chaos::{ChaosSchedule, RejectReason};
    use aibrix::engine::ModelSpec;
    use aibrix::gateway::{tier_index, AdmissionConfig, AdmissionCounters};
    use aibrix::harness::{run, HarnessConfig};
    use aibrix::workload::{tier_for, ArrivalProcess, BirdSqlConfig, BirdSqlWorkload};
    use std::collections::HashSet;

    forall(
        "overload-conservation",
        8, // each case is three full harness runs — keep the count tight
        |rng, _| {
            (
                rng.next_u64(),                  // seed
                rng.below(2) as usize,           // extra pods
                120 + rng.below(160) as usize,   // requests
                200.0 + rng.uniform(0.0, 600.0), // arrival rate (overload factor)
                rng.uniform(0.05, 0.4),          // interactive fraction
                rng.uniform(0.1, 0.5),           // batch fraction
                200_000 + rng.below(400_000),    // base TTFT budget, µs
                rng.below(2) == 0,               // chaos on/off
            )
        },
        |&(seed, extra_pods, n, rate, fi, fb, budget, chaos_on)| {
            // Chaos kills replicas, so those cases keep a survivor.
            let pods = if chaos_on { 2 + extra_pods } else { 1 + extra_pods };
            let nodes: Vec<u64> = (0..pods as u64).collect();
            let mk_cfg = |admission| HarnessConfig {
                engines: (0..pods)
                    .map(|i| {
                        let mut ec =
                            EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
                        ec.prefix_caching = true;
                        (ec, i as u64)
                    })
                    .collect(),
                policy: Policy::LeastRequest,
                arrival: ArrivalProcess::Poisson { rate },
                kv_pool: None,
                seed,
                deadline: 0,
                closed_loop_clients: 0,
                view: Default::default(),
                chaos: if chaos_on {
                    Some(ChaosSchedule::from_seed(seed, pods, &nodes, 2_000_000))
                } else {
                    None
                },
                recovery: Default::default(),
                admission,
            };
            let wl_seed = seed ^ 0xBEEF;
            let wl = || {
                BirdSqlWorkload::new(BirdSqlConfig {
                    n_requests: n,
                    n_schemas: 4,
                    schema_tokens_mean: 350,
                    question_tokens_mean: 90,
                    interactive_fraction: fi,
                    batch_fraction: fb,
                    ttft_budget_us: Some(budget),
                    seed: wl_seed,
                    ..Default::default()
                })
            };

            let r = run(mk_cfg(Some(AdmissionConfig::default())), &mut wl());
            if r.completions.len() + r.rejections.len() != n {
                return Err(format!(
                    "lost requests: {} completed + {} rejected != {n}",
                    r.completions.len(),
                    r.rejections.len()
                ));
            }
            let mut seen = HashSet::new();
            for id in r
                .completions
                .iter()
                .map(|c| c.id)
                .chain(r.rejections.iter().map(|&(id, _)| id))
            {
                if !seen.insert(id) {
                    return Err(format!("request {id} got two terminal outcomes"));
                }
            }
            // Pressure-lane reconciliation: recompute each shed id's tier
            // from the workload's deterministic map and compare against
            // the per-tier counters.
            let mut ledger = [0u64; 3];
            for &(id, reason) in &r.rejections {
                if reason == RejectReason::AdmissionShed {
                    ledger[tier_index(tier_for(wl_seed, id, fi, fb))] += 1;
                }
            }
            for t in 0..3 {
                let counted = r.admission.shed_pressure[t];
                let ok = if chaos_on { ledger[t] <= counted } else { ledger[t] == counted };
                if !ok {
                    return Err(format!(
                        "tier {t}: ledger {} vs counted {counted} pressure sheds (chaos={chaos_on})",
                        ledger[t]
                    ));
                }
            }
            // Deterministic replay.
            let r2 = run(mk_cfg(Some(AdmissionConfig::default())), &mut wl());
            if r.rejections != r2.rejections
                || r.completions.len() != r2.completions.len()
                || r.admission != r2.admission
            {
                return Err("protected run is not deterministic".into());
            }
            // Unprotected leg: counters untouched, conservation still holds
            // (doomed requests die at the engine, typed).
            let open = run(mk_cfg(None), &mut wl());
            if open.admission != AdmissionCounters::default() {
                return Err(format!("unprotected run touched counters: {:?}", open.admission));
            }
            if open.completions.len() + open.rejections.len() != n {
                return Err(format!(
                    "unprotected leg lost requests: {} + {} != {n}",
                    open.completions.len(),
                    open.rejections.len()
                ));
            }
            Ok(())
        },
    );
}
