//! Property tests for the ClusterView signal plane (ISSUE 5 invariants):
//!
//!   1. a snapshot is a *pure function* of (config, pod signals, pool
//!      state, session table): deterministic under scratch reuse, total
//!      (one `PodSnapshot` per source, in order), and identical whichever
//!      entry-point shape produced the signals — the engine-sim trait
//!      impl (harness) or pre-assembled [`PodSignals`] (serve-style);
//!   2. pool-fed residency signals (`pool_blocks_*`, and the pool-lifted
//!      `prefix_match_blocks`) equal a reference walk over the pool's own
//!      metadata (`block_owner`) for the prompt's block keys.

use aibrix::cluster::GpuKind;
use aibrix::engine::prefix::prompt_block_keys;
use aibrix::engine::{EngineConfig, EngineSim, EngineStats, ExternalKv, ModelSpec};
use aibrix::gateway::{ClusterView, ClusterViewConfig, CounterPod, PodSignalSource, PodSignals};
use aibrix::kvcache::{DistKvPool, KvPoolConfig};
use aibrix::pt::{forall, gen};
use aibrix::workload::Request;

fn req(tokens: Vec<u32>, session: u64) -> Request {
    Request {
        id: 0,
        session,
        tokens,
        output_len: 8,
        arrival: 0,
        model: "m".into(),
        adapter: None,
        user: 0,
        shared_prefix_len: 0,
        end_session: false,
        deadline: None,
        tier: Default::default(),
    }
}

/// Invariant 1a: deterministic + total over arbitrary raw signals, with
/// identical session-table history.
#[test]
fn prop_snapshot_deterministic_and_total() {
    forall(
        "clusterview-deterministic-total",
        300,
        |rng, _| {
            let n = 1 + gen::usize_up_to(rng, 8);
            let sigs: Vec<(bool, usize, f64, f64, usize)> = (0..n)
                .map(|_| {
                    (
                        rng.chance(0.8),
                        gen::usize_up_to(rng, 50),
                        rng.uniform(0.0, 1.0),
                        rng.uniform(0.0, 500_000.0),
                        gen::usize_up_to(rng, 12),
                    )
                })
                .collect();
            let tokens: Vec<u32> = (0..gen::usize_up_to(rng, 200))
                .map(|_| rng.below(1000) as u32)
                .collect();
            let session = rng.below(5);
            let routes: Vec<(u64, usize)> = (0..gen::usize_up_to(rng, 6))
                .map(|_| (rng.below(5), gen::usize_up_to(rng, n)))
                .collect();
            (sigs, tokens, session, routes)
        },
        |(sigs, tokens, session, routes)| {
            let mk_signals = || -> Vec<PodSignals> {
                sigs.iter()
                    .enumerate()
                    .map(|(i, &(ready, load, kv, lat, pmb))| PodSignals {
                        pod: i,
                        node: i as u64,
                        ready,
                        stats: EngineStats {
                            waiting: load,
                            running: load / 2,
                            kv_utilization: kv,
                            avg_latency_us: lat,
                            ..EngineStats::default()
                        },
                        local_match_blocks: pmb,
                        resident_adapters: vec![],
                    })
                    .collect()
            };
            let mk_view = || {
                let mut v = ClusterView::new(ClusterViewConfig::default());
                for &(s, p) in routes {
                    v.note_route(s, p);
                }
                v
            };
            let r = req(tokens.clone(), *session);
            let mut v1 = mk_view();
            let a = v1.snapshot(1_000, &r, &mut mk_signals(), None);
            let b = v1.snapshot(1_000, &r, &mut mk_signals(), None); // scratch reuse
            let c = mk_view().snapshot(1_000, &r, &mut mk_signals(), None);
            if a != b || a != c {
                return Err("snapshot not deterministic".into());
            }
            if a.len() != sigs.len() {
                return Err(format!("{} snapshots for {} pods", a.len(), sigs.len()));
            }
            for (i, s) in a.iter().enumerate() {
                if s.pod != i {
                    return Err(format!("pod order broken at {i}: {}", s.pod));
                }
                if s.prompt_blocks != (tokens.len() / 16).max(1) {
                    return Err(format!("prompt_blocks {} wrong", s.prompt_blocks));
                }
                let sticky = mk_view().session_pod(*session);
                if s.session_match != (sticky == Some(i)) {
                    return Err(format!("session_match wrong on pod {i}"));
                }
            }
            Ok(())
        },
    );
}

/// Invariant 1b: the harness entry point (EngineSim as the signal source)
/// and a serve-style entry point (signals extracted by hand from the same
/// engines) produce bit-identical snapshot vectors.
#[test]
fn prop_entry_points_agree() {
    forall(
        "clusterview-entrypoint-equivalence",
        60,
        |rng, size| {
            let n_engines = 1 + gen::usize_up_to(rng, 3);
            let reqs: Vec<(usize, usize, usize)> = (0..gen::usize_up_to(rng, size.0 / 8 + 2))
                .map(|_| {
                    (
                        gen::usize_up_to(rng, n_engines),
                        1 + gen::usize_up_to(rng, 1200),
                        1 + gen::usize_up_to(rng, 12),
                    )
                })
                .collect();
            let steps = gen::usize_up_to(rng, 6);
            let probe: Vec<u32> =
                (0..gen::usize_up_to(rng, 120)).map(|_| rng.below(64) as u32).collect();
            (n_engines, reqs, steps, probe)
        },
        |(n_engines, reqs, steps, probe)| {
            let mk_engines = || -> Vec<EngineSim> {
                let mut engines: Vec<EngineSim> = (0..*n_engines)
                    .map(|i| {
                        let mut ec =
                            EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
                        ec.prefix_caching = true;
                        EngineSim::new(i, i as u64, ec)
                    })
                    .collect();
                for (i, &(e, prompt, out)) in reqs.iter().enumerate() {
                    engines[e].enqueue(req(vec![(i % 50) as u32; prompt], 0));
                    let _ = out;
                }
                let mut now = 0;
                for _ in 0..*steps {
                    for e in engines.iter_mut() {
                        if let Some(dt) = e.step(now, None) {
                            now += dt / 2;
                        }
                    }
                }
                engines
            };
            let now = 10_000_000;
            let r = req(probe.clone(), 1);
            // Harness shape: EngineSim implements PodSignalSource.
            let mut engines_a = mk_engines();
            let mut view_a = ClusterView::new(ClusterViewConfig::default());
            view_a.note_route(1, 0);
            let snaps_a = view_a.snapshot(now, &r, &mut engines_a, None);
            // Serve shape: the same cluster state, signals pre-extracted.
            let mut engines_b = mk_engines();
            let keys = prompt_block_keys(&r.tokens, 16);
            let mut signals: Vec<PodSignals> =
                engines_b.iter_mut().map(|e| e.signals(now, &keys)).collect();
            let mut view_b = ClusterView::new(ClusterViewConfig::default());
            view_b.note_route(1, 0);
            let snaps_b = view_b.snapshot(now, &r, &mut signals, None);
            if snaps_a != snaps_b {
                return Err(format!(
                    "entry points disagree:\n harness: {snaps_a:?}\n serve:   {snaps_b:?}"
                ));
            }
            Ok(())
        },
    );
}

/// Invariant 2: pool-fed signals equal the pool's metadata. For every
/// node, `pool_blocks_total`/`pool_blocks_local` must match a reference
/// walk over `block_owner` with the same per-consumer visibility rule
/// (published, or homed on the consulting node), and `prefix_match_blocks`
/// is lifted to the pool-local count when no engine-local cache matches.
#[test]
fn prop_pool_residency_matches_metadata() {
    forall(
        "clusterview-pool-residency",
        200,
        |rng, _| {
            let blocks = gen::usize_up_to(rng, 10);
            let tokens: Vec<u32> = (0..blocks * 16).map(|_| rng.below(500) as u32).collect();
            // Per-block: (inserted?, writer node 0..4 — node 3 shard-less,
            // insert time).
            let inserts: Vec<(bool, u64, u64)> = (0..blocks)
                .map(|_| (rng.chance(0.7), rng.below(4), rng.below(200_000)))
                .collect();
            let now = rng.below(300_000);
            (tokens, inserts, now)
        },
        |(tokens, inserts, now)| {
            let mut pool = DistKvPool::new(KvPoolConfig::new(
                vec![(0, 1 << 30), (1, 1 << 30), (2, 1 << 30)],
                1024,
                16,
            ));
            let keys = prompt_block_keys(tokens, 16);
            for (key, &(present, node, t)) in keys.iter().zip(inserts) {
                if present {
                    pool.insert(t, node, &[*key], 16);
                }
            }
            let mut view = ClusterView::new(ClusterViewConfig::default());
            let r = req(tokens.clone(), 0);
            let mut pods: Vec<CounterPod> = (0..3)
                .map(|i| CounterPod {
                    pod: i,
                    node: i as u64,
                    ready: true,
                    waiting: 0,
                    running: 0,
                    kv_pressure: 0.0,
                    ..Default::default()
                })
                .collect();
            let snaps = view.snapshot(*now, &r, &mut pods, Some(&pool));
            for (i, snap) in snaps.iter().enumerate() {
                // Reference walk straight off the pool's metadata.
                let node = i as u64;
                let mut visible = 0usize;
                let mut local = 0usize;
                for key in &keys {
                    match pool.block_owner(*key) {
                        Some((owner, vis_at)) if vis_at <= *now || owner == node => {
                            visible += 1;
                            if owner == node {
                                local += 1;
                            }
                        }
                        _ => break,
                    }
                }
                if snap.pool_blocks_total != visible || snap.pool_blocks_local != local {
                    return Err(format!(
                        "pod {i}: snapshot ({}, {}) vs metadata ({visible}, {local})",
                        snap.pool_blocks_total, snap.pool_blocks_local
                    ));
                }
                if snap.prefix_match_blocks != local {
                    return Err(format!(
                        "pod {i}: prefix_match_blocks {} != pool-local {local}",
                        snap.prefix_match_blocks
                    ));
                }
            }
            Ok(())
        },
    );
}
