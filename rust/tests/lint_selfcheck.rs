//! Linter self-check: every rule family fires on its known-bad fixture,
//! clean code passes, suppressions behave as documented, and the real
//! repo tree lints clean.
//!
//! Fixtures live under rust/src/lint/fixtures/ and are excluded from the
//! tree walk itself; here they are linted under *virtual* serving-path
//! file names so the path-scoped rules engage.

use aibrix::lint::{
    Linter, Report, ALL_RULES, RULE_HOT, RULE_LOCK, RULE_PANIC, RULE_SUPPRESSION, RULE_UNSAFE,
};

const BAD_SERVING: &str = include_str!("../src/lint/fixtures/bad_serving_panic.rs");
const BAD_UNSAFE: &str = include_str!("../src/lint/fixtures/bad_unsafe_no_comment.rs");
const BAD_HOT: &str = include_str!("../src/lint/fixtures/bad_hot_alloc.rs");
const BAD_CYCLE: &str = include_str!("../src/lint/fixtures/bad_lock_cycle.rs");
const CLEAN: &str = include_str!("../src/lint/fixtures/clean.rs");
const ALLOW_REASON: &str = include_str!("../src/lint/fixtures/allow_with_reason.rs");
const ALLOW_BARE: &str = include_str!("../src/lint/fixtures/allow_missing_reason.rs");

/// Lint one fixture under a virtual path with a fresh linter (so lock
/// edges from one fixture never leak into another's graph).
fn lint_one(virtual_path: &str, src: &str) -> Report {
    let mut linter = Linter::new();
    linter.lint_source(virtual_path, src);
    linter.finish()
}

fn count_rule(report: &Report, rule: &str) -> usize {
    report.findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn serving_panic_fixture_fires() {
    let report = lint_one("rust/src/gateway/bad.rs", BAD_SERVING);
    // unwrap, expect, panic!, get_unchecked — and the test module's
    // unwrap stays exempt.
    assert_eq!(count_rule(&report, RULE_PANIC), 4, "{:?}", report.findings);
    // The unchecked-indexing site also lacks a SAFETY comment.
    assert_eq!(count_rule(&report, RULE_UNSAFE), 1, "{:?}", report.findings);
    assert!(report.suppressions.is_empty());
}

#[test]
fn unsafe_fixture_fires() {
    let report = lint_one("rust/src/runtime/bad.rs", BAD_UNSAFE);
    // unsafe block, unsafe fn, unsafe impl — each without a SAFETY note.
    assert_eq!(count_rule(&report, RULE_UNSAFE), 3, "{:?}", report.findings);
    assert_eq!(report.findings.len(), 3, "{:?}", report.findings);
}

#[test]
fn hot_alloc_fixture_fires() {
    let report = lint_one("rust/src/runtime/hot.rs", BAD_HOT);
    // Vec::new, vec!, to_vec, collect, clone — all in the tagged fn; the
    // untagged sibling allocates freely.
    assert_eq!(count_rule(&report, RULE_HOT), 5, "{:?}", report.findings);
    for f in &report.findings {
        assert!(f.message.contains("decode_step"), "{}", f.message);
    }
}

#[test]
fn lock_cycle_fixture_fires() {
    let report = lint_one("rust/src/gateway/cycle.rs", BAD_CYCLE);
    let lock_findings: Vec<_> = report.findings.iter().filter(|f| f.rule == RULE_LOCK).collect();
    assert_eq!(lock_findings.len(), 2, "{:?}", report.findings);
    assert!(
        lock_findings.iter().any(|f| f.message.contains("back-edge")),
        "{lock_findings:?}"
    );
    let cycle = lock_findings
        .iter()
        .find(|f| f.message.contains("lock-order cycle"))
        .expect("cycle finding");
    assert!(
        cycle.message.contains("gateway → ClusterView → DistKvPool → gateway"),
        "{}",
        cycle.message
    );
}

#[test]
fn clean_fixture_passes() {
    let report = lint_one("rust/src/gateway/clean.rs", CLEAN);
    assert!(report.ok(), "{:?}", report.findings);
    assert!(report.suppressions.is_empty(), "{:?}", report.suppressions);
}

#[test]
fn allow_with_reason_suppresses_and_is_reported() {
    let report = lint_one("rust/src/gateway/allow.rs", ALLOW_REASON);
    assert!(report.ok(), "{:?}", report.findings);
    assert_eq!(report.suppressions.len(), 1, "{:?}", report.suppressions);
    let s = &report.suppressions[0];
    assert_eq!(s.rule, RULE_PANIC);
    assert_eq!(s.reason, "guarded by is_some() at the sole call site");
}

#[test]
fn allow_without_reason_is_a_finding() {
    let report = lint_one("rust/src/gateway/bare_allow.rs", ALLOW_BARE);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    assert_eq!(report.findings[0].rule, RULE_SUPPRESSION);
    // The target finding is still suppressed — but with an empty reason
    // on record, which the CI schema check also rejects.
    assert_eq!(report.suppressions.len(), 1);
    assert!(report.suppressions[0].reason.is_empty());
}

#[test]
fn every_rule_fires_at_least_once_across_fixtures() {
    let reports = [
        lint_one("rust/src/gateway/bad.rs", BAD_SERVING),
        lint_one("rust/src/runtime/bad.rs", BAD_UNSAFE),
        lint_one("rust/src/runtime/hot.rs", BAD_HOT),
        lint_one("rust/src/gateway/cycle.rs", BAD_CYCLE),
        lint_one("rust/src/gateway/bare_allow.rs", ALLOW_BARE),
    ];
    for rule in ALL_RULES {
        assert!(
            reports.iter().any(|r| r.findings.iter().any(|f| f.rule == rule)),
            "rule {rule} never fired on any fixture"
        );
    }
}

#[test]
fn real_tree_lints_clean() {
    // CARGO_MANIFEST_DIR is rust/; the linted roots hang off its parent.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root above rust/")
        .to_path_buf();
    let report = aibrix::lint::lint_tree(&root).expect("walk repo tree");
    assert!(report.files_scanned > 20, "only {} files scanned", report.files_scanned);
    assert!(report.ok(), "repo tree has lint findings:\n{}", report.render_human());
    for s in &report.suppressions {
        assert!(
            !s.reason.is_empty(),
            "suppression without reason at {}:{}",
            s.file,
            s.line
        );
    }
}
