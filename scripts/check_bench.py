#!/usr/bin/env python3
"""Gate BENCH_*.json runs against a checked-in baseline (BENCHMARKS.md).

Usage:
    python3 scripts/check_bench.py CURRENT BASELINE [--bless] [--tolerance T]
    python3 scripts/check_bench.py --kvpool BENCH_kvpool_e2e.json
    python3 scripts/check_bench.py --kvpool-tiered BENCH_kvpool_tiered.json
    python3 scripts/check_bench.py --routing BENCH_routing_e2e.json
    python3 scripts/check_bench.py --chaos BENCH_chaos_e2e.json
    python3 scripts/check_bench.py --sched BENCH_engine_sched_e2e.json
    python3 scripts/check_bench.py --overload BENCH_overload_e2e.json
    python3 scripts/check_bench.py --lint lint_report.json

- CURRENT: the BENCH_runtime.json a bench run just wrote.
- BASELINE: the blessed copy tracked in git (benchmarks/*.baseline.json).
- --bless: copy CURRENT over BASELINE (run locally, commit the result).
- --tolerance: allowed fractional regression (default 0.30, i.e. fail if
  decode tokens/s drops more than 30% below the baseline).
- --kvpool: validate a kvpool_e2e report instead — within-run gates only
  (pool-on beats pool-off, cross-replica hits happened, outputs
  bit-identical); no baseline needed, so it is never in record mode for
  these structural checks.
- --kvpool-tiered: validate a kvpool_tiered report — within-run gates only
  (strict served-throughput ordering tiered > ram_only_f32 > pool_off, the
  cold tier actually spilled and promoted, end-of-turn prefetch hit at
  least once, ram-only outputs bit-identical, and int8 greedy top-1
  agreement >= 0.5).
- --routing: validate a routing_e2e report — within-run gates only
  (pool-aware hit ratio strictly above pool-blind, served-prefill
  throughput at least pool-blind's, session-sticky above blind, outputs
  bit-identical across policies).
- --chaos: validate a chaos_e2e report — within-run gates only (zero lost
  requests, outputs bit-identical to the fault-free run, a positive
  detect-to-cordon latency, stranded requests recovered, and P99 latency
  degradation within the report's own target).
- --sched: validate an engine_sched_e2e report — within-run gates only
  (the continuous-batching scheduler strictly beats the lockstep engine
  on served tok/s and P99 TTFT, outputs bit-identical, and the tight-KV
  leg actually preempted while staying bit-identical).
- --overload: validate an overload_e2e report — within-run gates only
  (the protected plane achieves strictly higher goodput than the
  unprotected run, Interactive P99 TTFT lands within the calibrated SLO,
  both overload legs conserve every request as one completion or one
  typed rejection, and served outputs stay bit-identical — or a Batch
  brownout prefix — to the uncontended reference).
- --lint: validate an `aibrix_lint --json` report — schema (version 1,
  files_scanned, findings, suppressions), zero findings, and every
  suppression carrying a non-empty reason. This is the CI hard gate for
  the static-analysis pass (README "Static analysis & invariants").

Exit codes: 0 = ok (or record mode: no baseline checked in yet),
1 = regression, 2 = malformed input.

Throughput metrics compared (higher is better): decode_kernel and
prefill_kernel `tokens_per_s`. Only decode gates (prefill is reported);
machine-to-machine noise is why the tolerance is wide — the within-run
`decode_speedup` vs the scalar reference is the portable number. The
kvpool gate likewise uses the within-run `pool_speedup`.

Portable within-run gates (machine-independent, checked on every run
regardless of baseline): `decode_speedup` must stay >= 0.8 (the kernel
path must not fall behind the scalar reference it replaced) and, when the
report carries the quantized axis, `quant_top1_ok` must be true (int8
greedy top-1 agreement >= 0.5). `quant_decode_speedup` vs its 1.5x target
is reported informationally — absolute quant wins are machine-dependent
(bandwidth-bound), so the hard floor lives in the bench itself.
"""

import json
import shutil
import sys


def tokens_per_s(doc, name):
    for row in doc.get("results", []):
        if row.get("name") == name:
            return row.get("tokens_per_s")
    return None


def check_kvpool(path):
    """Within-run validation of a kvpool_e2e report (ISSUE 3 acceptance:
    remote hits > 0, pool-on beats pool-off, bit-identical outputs)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read kvpool report {path}: {e}")
        return 2
    on = tokens_per_s(doc, "pool_on_prefill")
    off = tokens_per_s(doc, "pool_off_prefill")
    derived = doc.get("derived", {})
    speedup = derived.get("pool_speedup")
    remote = derived.get("blocks_hit_remote")
    identical = derived.get("outputs_bit_identical")
    if None in (on, off, speedup, remote, identical):
        print(f"check_bench: {path} is missing kvpool rows/derived values")
        return 2
    print(f"check_bench: kvpool pool-on {on:.0f} vs pool-off {off:.0f} served tok/s "
          f"(speedup {speedup:.2f}x, {remote} remote block hits)")
    if identical is not True:
        print("check_bench: FAIL — seeded outputs were not bit-identical")
        return 1
    if remote <= 0:
        print("check_bench: FAIL — no cross-replica block reuse recorded")
        return 1
    if speedup <= 1.0:
        print("check_bench: FAIL — pool-on did not beat pool-off")
        return 1
    # Wall clock is noisy on shared runners: only a *material* end-to-end
    # slowdown fails (the deterministic pool_speedup gate is above).
    wall = derived.get("wall_speedup")
    if wall is not None and wall <= 0.9:
        print(f"check_bench: FAIL — pool overheads outweighed the saved "
              f"prefill (wall speedup {wall:.2f}x)")
        return 1
    print("check_bench: OK — kvpool within-run gates hold")
    return 0


def check_kvpool_tiered(path):
    """Within-run validation of a kvpool_tiered report (ISSUE 10
    acceptance: with the working set over RAM capacity, the tiered cache
    — int8 blocks + cold spill + prefetch — strictly beats both the
    thrashing RAM-only f32 pool and no pool at all, the cold tier did
    real work, prefetch landed, and quantization drift stayed inside the
    relaxed top-1 floor)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read kvpool-tiered report {path}: {e}")
        return 2
    off = tokens_per_s(doc, "pool_off")
    ram = tokens_per_s(doc, "ram_only_f32")
    tiered = tokens_per_s(doc, "tiered")
    derived = doc.get("derived", {})
    spills = derived.get("spills")
    promotions = derived.get("promotions")
    cold_end = derived.get("cold_blocks_end")
    pf_issued = derived.get("prefetch_issued")
    pf_hits = derived.get("prefetch_hits")
    pf_rate = derived.get("prefetch_hit_rate")
    top1 = derived.get("top1_agreement")
    ram_identical = derived.get("ram_only_outputs_bit_identical")
    if None in (off, ram, tiered, spills, promotions, cold_end, pf_issued,
                pf_hits, pf_rate, top1, ram_identical):
        print(f"check_bench: {path} is missing kvpool-tiered rows/derived values")
        return 2
    print(f"check_bench: kvpool-tiered {tiered:.0f} vs ram-only {ram:.0f} vs "
          f"pool-off {off:.0f} served tok/s ({int(spills)} spills, "
          f"{int(promotions)} promotions, prefetch {int(pf_hits)}/{int(pf_issued)} "
          f"hit, top-1 {top1:.3f})")
    if ram_identical is not True:
        print("check_bench: FAIL — ram-only f32 outputs were not bit-identical "
              "to pool-off")
        return 1
    if not ram > off:
        print("check_bench: FAIL — ram-only f32 pool did not beat pool-off")
        return 1
    if not tiered > ram:
        print("check_bench: FAIL — tiered cache did not beat the ram-only f32 pool")
        return 1
    if not spills > 0:
        print("check_bench: FAIL — the working set never spilled to the cold tier "
              "(the tiered gate is vacuous)")
        return 1
    if not promotions > 0:
        print("check_bench: FAIL — no cold block was ever promoted back to RAM")
        return 1
    if not cold_end > 0:
        print("check_bench: FAIL — cold tier empty at end of run")
        return 1
    if not (pf_issued > 0 and pf_hits > 0 and pf_rate > 0):
        print("check_bench: FAIL — end-of-turn prefetch never warmed a block")
        return 1
    if top1 < 0.5:
        print(f"check_bench: FAIL — int8 KV drift broke greedy top-1 agreement "
              f"({top1:.3f} < 0.5)")
        return 1
    print("check_bench: OK — kvpool-tiered within-run gates hold")
    return 0


def check_routing(path):
    """Within-run validation of a routing_e2e report (ISSUE 5 acceptance:
    pool-aware routing strictly lifts the hit ratio, never costs served
    prefill throughput, and completions stay bit-identical)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read routing report {path}: {e}")
        return 2
    aware = tokens_per_s(doc, "pool_aware")
    blind = tokens_per_s(doc, "pool_blind_random")
    derived = doc.get("derived", {})
    aware_hits = derived.get("aware_hit_ratio")
    blind_hits = derived.get("blind_hit_ratio")
    sticky_hits = derived.get("sticky_hit_ratio")
    speedup = derived.get("aware_speedup")
    identical = derived.get("outputs_bit_identical")
    if None in (aware, blind, aware_hits, blind_hits, sticky_hits, speedup, identical):
        print(f"check_bench: {path} is missing routing rows/derived values")
        return 2
    print(f"check_bench: routing pool-aware {aware:.0f} vs pool-blind {blind:.0f} "
          f"served tok/s (speedup {speedup:.2f}x, hit ratio {aware_hits:.2f} vs "
          f"{blind_hits:.2f}, sticky {sticky_hits:.2f})")
    if identical is not True:
        print("check_bench: FAIL — routing policy changed completions")
        return 1
    if aware_hits <= blind_hits:
        print("check_bench: FAIL — pool-aware hit ratio did not beat pool-blind")
        return 1
    if sticky_hits <= blind_hits:
        print("check_bench: FAIL — session-sticky hit ratio did not beat pool-blind")
        return 1
    if speedup < 1.0:
        print("check_bench: FAIL — pool-aware served prefill fell behind pool-blind")
        return 1
    print("check_bench: OK — routing within-run gates hold")
    return 0


def check_chaos(path):
    """Within-run validation of a chaos_e2e report (ISSUE 7 acceptance:
    kill a replica mid-trace + drop a pool shard — zero lost requests,
    bit-identical outputs, the incident detected and cordoned, bounded
    P99 degradation)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read chaos report {path}: {e}")
        return 2
    derived = doc.get("derived", {})
    total = derived.get("total_requests")
    lost = derived.get("lost_requests")
    identical = derived.get("outputs_bit_identical")
    recovered = derived.get("recovered_requests")
    detect = derived.get("detect_to_cordon_us")
    degradation = derived.get("p99_ttft_degradation")
    target = derived.get("p99_ttft_degradation_target", 8.0)
    if None in (total, lost, identical, recovered, detect, degradation):
        print(f"check_bench: {path} is missing chaos derived values")
        return 2
    print(f"check_bench: chaos {total} requests, {lost} lost, {recovered} "
          f"recovered, detect-to-cordon {detect}µs, p99 degradation "
          f"{degradation:.2f}x (target <= {target}x)")
    if lost != 0:
        print(f"check_bench: FAIL — chaos run lost {lost} request(s)")
        return 1
    if identical is not True:
        print("check_bench: FAIL — recovery changed completions")
        return 1
    if recovered <= 0:
        print("check_bench: FAIL — the incident stranded no requests "
              "(fault fired with an empty queue; the drill proves nothing)")
        return 1
    if not detect > 0 or detect >= 1_000_000:
        print(f"check_bench: FAIL — detect-to-cordon latency {detect}µs "
              f"out of range (0, 1s)")
        return 1
    if degradation > target:
        print(f"check_bench: FAIL — p99 degradation {degradation:.2f}x "
              f"exceeds the {target}x budget")
        return 1
    print("check_bench: OK — chaos within-run gates hold")
    return 0


def check_sched(path):
    """Within-run validation of an engine_sched_e2e report (ISSUE 8
    acceptance: the continuous-batching scheduler strictly beats the
    lockstep engine on served tok/s AND P99 TTFT on the same bursty
    trace, per-request outputs bit-identical, and the tight-KV-budget
    leg preempts at least once without changing a bit)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read sched report {path}: {e}")
        return 2
    lock = tokens_per_s(doc, "lockstep")
    sched = tokens_per_s(doc, "sched")
    derived = doc.get("derived", {})
    speedup = derived.get("sched_speedup")
    ttft = derived.get("ttft_improvement")
    identical = derived.get("outputs_bit_identical")
    tight_identical = derived.get("tight_outputs_bit_identical")
    preemptions = derived.get("tight_preemptions")
    if None in (lock, sched, speedup, ttft, identical, tight_identical, preemptions):
        print(f"check_bench: {path} is missing sched rows/derived values")
        return 2
    print(f"check_bench: sched {sched:.0f} vs lockstep {lock:.0f} served tok/s "
          f"(speedup {speedup:.2f}x, p99 TTFT improvement {ttft:.2f}x, "
          f"{int(preemptions)} tight-leg preemptions)")
    if identical is not True:
        print("check_bench: FAIL — scheduler changed completions vs lockstep")
        return 1
    if tight_identical is not True:
        print("check_bench: FAIL — preemption changed completions")
        return 1
    if speedup <= 1.0:
        print("check_bench: FAIL — scheduler did not beat lockstep on served tok/s")
        return 1
    if ttft <= 1.0:
        print("check_bench: FAIL — scheduler did not beat lockstep on p99 TTFT")
        return 1
    if preemptions <= 0:
        print("check_bench: FAIL — tight-KV leg never preempted (gate is vacuous)")
        return 1
    print("check_bench: OK — sched within-run gates hold")
    return 0


def check_overload(path):
    """Within-run validation of an overload_e2e report (ISSUE 9
    acceptance: protected goodput strictly above unprotected, Interactive
    P99 TTFT within the calibrated SLO, conservation in both overload
    legs, and served outputs bit-identical — or a Batch brownout prefix —
    to the uncontended reference)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read overload report {path}: {e}")
        return 2
    derived = doc.get("derived", {})
    gp_prot = derived.get("goodput_protected")
    gp_unprot = derived.get("goodput_unprotected")
    p99 = derived.get("interactive_p99_ttft_us")
    slo = derived.get("slo_ttft_us")
    out_prot = derived.get("outputs_ok_protected")
    out_unprot = derived.get("outputs_ok_unprotected")
    conserved = (derived.get("conserved_protected"),
                 derived.get("conserved_unprotected"))
    total = derived.get("total_requests")
    if None in (gp_prot, gp_unprot, p99, slo, out_prot, out_unprot, total) \
            or None in conserved:
        print(f"check_bench: {path} is missing overload derived values")
        return 2
    print(f"check_bench: overload {total} requests, goodput protected "
          f"{gp_prot:.1f}/s vs unprotected {gp_unprot:.1f}/s, Interactive "
          f"P99 TTFT {p99 / 1e3:.1f}ms vs SLO {slo / 1e3:.1f}ms")
    if gp_prot <= gp_unprot:
        print("check_bench: FAIL — the overload plane did not lift goodput")
        return 1
    if p99 > slo:
        print("check_bench: FAIL — protected Interactive P99 TTFT blew the SLO")
        return 1
    if conserved != (True, True):
        print(f"check_bench: FAIL — a leg lost requests (conserved "
              f"protected/unprotected = {conserved})")
        return 1
    if out_prot is not True or out_unprot is not True:
        print("check_bench: FAIL — served outputs diverged from the "
              "uncontended reference (beyond the Batch brownout prefix)")
        return 1
    rows = {r.get("name"): r for r in doc.get("results", [])}
    prot = rows.get("protected", {})
    if not prot.get("gateway_sheds", 0) > 0:
        print("check_bench: FAIL — the protected leg never shed at the "
              "gateway (the overload gate is vacuous)")
        return 1
    print("check_bench: OK — overload within-run gates hold")
    return 0


def check_lint(path):
    """Validate an aibrix_lint --json report (ISSUE 6 acceptance: schema
    well-formed, zero findings, every suppression has a reason)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read lint report {path}: {e}")
        return 2
    if doc.get("version") != 1:
        print(f"check_bench: {path} has unknown lint schema version "
              f"{doc.get('version')!r} (expected 1)")
        return 2
    scanned = doc.get("files_scanned")
    findings = doc.get("findings")
    suppressions = doc.get("suppressions")
    if not isinstance(scanned, (int, float)) or scanned <= 0 \
            or not isinstance(findings, list) or not isinstance(suppressions, list):
        print(f"check_bench: {path} is missing files_scanned/findings/suppressions")
        return 2
    for row in findings + suppressions:
        if not isinstance(row, dict) or not isinstance(row.get("file"), str) \
                or not isinstance(row.get("line"), (int, float)):
            print(f"check_bench: {path} has a malformed finding/suppression row: {row!r}")
            return 2
    print(f"check_bench: lint scanned {int(scanned)} files, "
          f"{len(findings)} finding(s), {len(suppressions)} suppression(s)")
    if findings:
        for f in findings:
            print(f"  {f.get('file')}:{int(f.get('line', 0))}: "
                  f"[{f.get('rule')}] {f.get('message')}")
        print("check_bench: FAIL — lint findings present")
        return 1
    bare = [s for s in suppressions if not str(s.get("reason", "")).strip()]
    if bare:
        for s in bare:
            print(f"  {s.get('file')}:{int(s.get('line', 0))}: "
                  f"allow({s.get('rule')}) has no reason")
        print("check_bench: FAIL — suppression(s) without a reason")
        return 1
    print("check_bench: OK — lint gate holds (zero findings, reasoned suppressions)")
    return 0


def main(argv):
    bless = False
    tol = 0.30
    kvpool = None
    kvpool_tiered = None
    routing = None
    chaos = None
    sched = None
    overload = None
    lint = None
    args = []
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--bless":
            bless = True
        elif a in ("--tolerance", "--kvpool", "--kvpool-tiered", "--routing",
                   "--chaos", "--sched", "--overload", "--lint"):
            i += 1
            if i >= len(argv):
                print(f"check_bench: {a} expects a value")
                print(__doc__)
                return 2
            if a == "--tolerance":
                tol = float(argv[i])
            elif a == "--kvpool":
                kvpool = argv[i]
            elif a == "--kvpool-tiered":
                kvpool_tiered = argv[i]
            elif a == "--chaos":
                chaos = argv[i]
            elif a == "--sched":
                sched = argv[i]
            elif a == "--overload":
                overload = argv[i]
            elif a == "--lint":
                lint = argv[i]
            else:
                routing = argv[i]
        elif a.startswith("--"):
            print(f"check_bench: unknown flag {a}")
            print(__doc__)
            return 2
        else:
            args.append(a)
        i += 1
    if sum(x is not None for x in (kvpool, kvpool_tiered, routing, chaos, sched,
                                   overload, lint)) > 1:
        print("check_bench: pass one of --kvpool/--kvpool-tiered/--routing/"
              "--chaos/--sched/--overload/--lint (run twice)")
        print(__doc__)
        return 2
    if chaos is not None:
        if args:
            print("check_bench: --chaos takes no positional arguments")
            print(__doc__)
            return 2
        return check_chaos(chaos)
    if sched is not None:
        if args:
            print("check_bench: --sched takes no positional arguments")
            print(__doc__)
            return 2
        return check_sched(sched)
    if overload is not None:
        if args:
            print("check_bench: --overload takes no positional arguments")
            print(__doc__)
            return 2
        return check_overload(overload)
    if lint is not None:
        if args:
            print("check_bench: --lint takes no positional arguments")
            print(__doc__)
            return 2
        return check_lint(lint)
    if kvpool is not None:
        if args:
            print("check_bench: --kvpool takes no positional arguments")
            print(__doc__)
            return 2
        return check_kvpool(kvpool)
    if kvpool_tiered is not None:
        if args:
            print("check_bench: --kvpool-tiered takes no positional arguments")
            print(__doc__)
            return 2
        return check_kvpool_tiered(kvpool_tiered)
    if routing is not None:
        if args:
            print("check_bench: --routing takes no positional arguments")
            print(__doc__)
            return 2
        return check_routing(routing)
    if len(args) != 2:
        print(__doc__)
        return 2
    current_path, baseline_path = args

    try:
        with open(current_path) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read current run {current_path}: {e}")
        return 2

    cur_decode = tokens_per_s(current, "decode_kernel")
    if cur_decode is None:
        print(f"check_bench: {current_path} has no decode_kernel result")
        return 2
    derived = current.get("derived", {})
    speedup = derived.get("decode_speedup")
    print(f"check_bench: current decode_kernel {cur_decode:.0f} tok/s "
          f"(speedup vs scalar reference: {speedup})")

    # Portable within-run gates — these do not need a baseline.
    if isinstance(speedup, (int, float)) and speedup < 0.8:
        print(f"check_bench: FAIL — kernel decode fell behind the scalar "
              f"reference (within-run speedup {speedup:.2f}x < 0.8x)")
        return 1
    qspeed = derived.get("quant_decode_speedup")
    qtarget = derived.get("target_quant_decode_speedup")
    qagree = derived.get("quant_top1_agreement")
    if qspeed is not None:
        print(f"check_bench: quant decode speedup {qspeed:.2f}x vs f32 kernel "
              f"(target {qtarget}, top-1 agreement {qagree})")
    if derived.get("quant_top1_ok") is False:
        print("check_bench: FAIL — int8 greedy top-1 agreement fell below "
              "the relaxed-exactness floor (quant_top1_ok=false)")
        return 1

    if bless:
        shutil.copyfile(current_path, baseline_path)
        print(f"check_bench: blessed {current_path} -> {baseline_path}")
        return 0

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except OSError:
        print(f"check_bench: no baseline at {baseline_path} — record mode.")
        print("  To start gating, bless this run on a quiet machine and commit it:")
        print(f"    python3 scripts/check_bench.py {current_path} {baseline_path} --bless")
        return 0

    base_decode = tokens_per_s(baseline, "decode_kernel")
    if not base_decode:
        print(f"check_bench: baseline {baseline_path} has no decode_kernel result")
        return 2

    base_prefill = tokens_per_s(baseline, "prefill_kernel")
    cur_prefill = tokens_per_s(current, "prefill_kernel")
    if base_prefill and cur_prefill:
        print(f"check_bench: prefill_kernel {cur_prefill:.0f} tok/s "
              f"(baseline {base_prefill:.0f}, informational)")

    floor = (1.0 - tol) * base_decode
    if cur_decode < floor:
        print(f"check_bench: FAIL — decode_kernel {cur_decode:.0f} tok/s is below "
              f"{floor:.0f} (baseline {base_decode:.0f} - {tol:.0%} tolerance)")
        return 1
    print(f"check_bench: OK — decode_kernel {cur_decode:.0f} tok/s >= "
          f"{floor:.0f} (baseline {base_decode:.0f} - {tol:.0%} tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
