#!/usr/bin/env python3
"""Gate BENCH_*.json runs against a checked-in baseline (BENCHMARKS.md).

Usage:
    python3 scripts/check_bench.py CURRENT BASELINE [--bless] [--tolerance T]

- CURRENT: the BENCH_runtime.json a bench run just wrote.
- BASELINE: the blessed copy tracked in git (benchmarks/*.baseline.json).
- --bless: copy CURRENT over BASELINE (run locally, commit the result).
- --tolerance: allowed fractional regression (default 0.30, i.e. fail if
  decode tokens/s drops more than 30% below the baseline).

Exit codes: 0 = ok (or record mode: no baseline checked in yet),
1 = regression, 2 = malformed input.

Throughput metrics compared (higher is better): decode_kernel and
prefill_kernel `tokens_per_s`. Only decode gates (prefill is reported);
machine-to-machine noise is why the tolerance is wide — the within-run
`decode_speedup` vs the scalar reference is the portable number.
"""

import json
import shutil
import sys


def tokens_per_s(doc, name):
    for row in doc.get("results", []):
        if row.get("name") == name:
            return row.get("tokens_per_s")
    return None


def main(argv):
    bless = False
    tol = 0.30
    args = []
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--bless":
            bless = True
        elif a == "--tolerance":
            i += 1
            tol = float(argv[i])
        elif a.startswith("--"):
            print(f"check_bench: unknown flag {a}")
            print(__doc__)
            return 2
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 2
    current_path, baseline_path = args

    try:
        with open(current_path) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read current run {current_path}: {e}")
        return 2

    cur_decode = tokens_per_s(current, "decode_kernel")
    if cur_decode is None:
        print(f"check_bench: {current_path} has no decode_kernel result")
        return 2
    speedup = current.get("derived", {}).get("decode_speedup")
    print(f"check_bench: current decode_kernel {cur_decode:.0f} tok/s "
          f"(speedup vs scalar reference: {speedup})")

    if bless:
        shutil.copyfile(current_path, baseline_path)
        print(f"check_bench: blessed {current_path} -> {baseline_path}")
        return 0

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except OSError:
        print(f"check_bench: no baseline at {baseline_path} — record mode.")
        print("  To start gating, bless this run on a quiet machine and commit it:")
        print(f"    python3 scripts/check_bench.py {current_path} {baseline_path} --bless")
        return 0

    base_decode = tokens_per_s(baseline, "decode_kernel")
    if not base_decode:
        print(f"check_bench: baseline {baseline_path} has no decode_kernel result")
        return 2

    base_prefill = tokens_per_s(baseline, "prefill_kernel")
    cur_prefill = tokens_per_s(current, "prefill_kernel")
    if base_prefill and cur_prefill:
        print(f"check_bench: prefill_kernel {cur_prefill:.0f} tok/s "
              f"(baseline {base_prefill:.0f}, informational)")

    floor = (1.0 - tol) * base_decode
    if cur_decode < floor:
        print(f"check_bench: FAIL — decode_kernel {cur_decode:.0f} tok/s is below "
              f"{floor:.0f} (baseline {base_decode:.0f} - {tol:.0%} tolerance)")
        return 1
    print(f"check_bench: OK — decode_kernel {cur_decode:.0f} tok/s >= "
          f"{floor:.0f} (baseline {base_decode:.0f} - {tol:.0%} tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
