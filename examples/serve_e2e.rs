//! End-to-end driver (DESIGN.md EXP-E2E): REAL model, REAL compute, full
//! stack composition.
//!
//! Loads the AOT-compiled TinyLM artifacts (JAX+Pallas -> HLO text -> PJRT),
//! spins TWO engine-replica threads behind the in-process HTTP gateway, and
//! serves 60 batched text completions over actual HTTP, reporting
//! latency/throughput. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example serve_e2e`

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use aibrix::engine::real::{EngineOpts, EnginePool, RealEngineHandle, RealRequest};
use aibrix::json::{parse, Json};
use aibrix::runtime::{Manifest, Precision};
use aibrix::server::{http_request, Handler, HttpRequest, HttpResponse, HttpServer};
use aibrix::tokenizer::Tokenizer;
use aibrix::util::stats::Summary;

fn main() -> aibrix::util::err::Result<()> {
    let artifacts = PathBuf::from(
        std::env::var("AIBRIX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        eprintln!("no artifacts at {artifacts:?} — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("loading TinyLM artifacts (PJRT compile)...");
    let t_load = Instant::now();
    // Replica count sized to the host: each PJRT client owns an intra-op
    // thread pool, so replicas beyond the core count only thrash
    // (§Perf iteration 2: 2 replicas on a 1-core host ran 2.4x slower).
    let n_replicas = std::thread::available_parallelism()
        .map(|p| p.get().min(4))
        .unwrap_or(1)
        .min(2);
    // The replicas share a distributed KV pool (one shard each): templated
    // SQL prompts share long token prefixes, so whichever replica prefills
    // a prefix first spares every other replica that compute.
    let manifest = Manifest::load(&artifacts)?;
    // Precision tier from AIBRIX_RT_PRECISION (int8 = quantized weights);
    // the pool's model id carries it so tiers never exchange KV bits.
    let precision = Precision::from_env();
    let model_id = format!("tinylm+{}", precision.name());
    let hook = EnginePool::for_model(&manifest.cfg, &model_id, n_replicas, 64 << 20);
    let replicas: Vec<RealEngineHandle> = (0..n_replicas)
        .map(|node| {
            RealEngineHandle::spawn_with_opts(
                &artifacts,
                EngineOpts {
                    pool: Some(hook.for_node(node as u64)),
                    precision: Some(precision),
                },
            )
        })
        .collect::<aibrix::util::err::Result<_>>()?;
    println!(
        "{} engine replica(s) ready in {:.1}s (vocab={}, prompt window={}, decode budget={}, \
         precision={})",
        replicas.len(),
        t_load.elapsed().as_secs_f64(),
        replicas[0].vocab,
        replicas[0].max_prompt,
        replicas[0].max_new_tokens,
        replicas[0].precision.name()
    );

    let tokenizer = Tokenizer::new(replicas[0].vocab as u32);
    let max_prompt = replicas[0].max_prompt;
    let max_new = replicas[0].max_new_tokens;
    let rr = Arc::new(AtomicUsize::new(0));
    let ids = Arc::new(AtomicUsize::new(0));

    // Gateway: least-loaded isn't observable over the handle, so this demo
    // round-robins across replicas (the sim harness exercises the smart
    // policies; here the point is real compute end-to-end).
    let handler: Handler = {
        let replicas = replicas.clone();
        let tokenizer = tokenizer.clone();
        Arc::new(move |req: &HttpRequest| {
            if req.method != "POST" || req.path != "/v1/completions" {
                return HttpResponse::text(404, "not found");
            }
            let Ok(body) = parse(&req.body_str()) else {
                return HttpResponse::json(400, r#"{"error":"bad json"}"#);
            };
            let prompt = body["prompt"].as_str().unwrap_or("");
            let max_tokens = body["max_tokens"].as_usize().unwrap_or(8).clamp(1, max_new);
            let mut tokens = tokenizer.encode(prompt);
            tokens.truncate(max_prompt);
            if tokens.is_empty() {
                tokens.push(tokenizer.bos());
            }
            let id = ids.fetch_add(1, Ordering::Relaxed) as u64;
            let replica = &replicas[rr.fetch_add(1, Ordering::Relaxed) % replicas.len()];
            match replica.serve(RealRequest {
                id,
                tokens,
                max_new_tokens: max_tokens,
                ..Default::default()
            }) {
                Ok(c) => {
                    let out = Json::obj([
                        ("text", Json::from(tokenizer.decode(&c.generated))),
                        ("completion_tokens", Json::from(c.generated.len())),
                        ("latency_us", Json::from(c.latency_us())),
                        ("serve_us", Json::from(c.serve_us)),
                    ]);
                    HttpResponse::json(200, &out.to_string())
                }
                Err(e) => HttpResponse::json(500, &format!(r#"{{"error":"{e}"}}"#)),
            }
        })
    };
    let server = HttpServer::start("127.0.0.1:0", 8, handler)?;
    let addr = server.addr();
    println!("gateway live on http://{addr}\n");

    // Client side: 6 threads x 10 requests of mixed SQL-ish prompts.
    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 10;
    const MAX_TOKENS: usize = 12;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                let mut toks = 0usize;
                for i in 0..PER_CLIENT {
                    let prompt = format!(
                        "SELECT name, total FROM orders WHERE customer_{c} = {i} ORDER BY total DESC LIMIT 5;"
                    );
                    let body = format!(
                        r#"{{"prompt":"{prompt}","max_tokens":{MAX_TOKENS}}}"#
                    );
                    let t = Instant::now();
                    let (code, resp) =
                        http_request(&addr, "POST", "/v1/completions", &body).unwrap();
                    assert_eq!(code, 200, "{resp}");
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    let j = parse(&resp).unwrap();
                    toks += j["completion_tokens"].as_usize().unwrap_or(0);
                    assert!(!j["text"].as_str().unwrap_or("").is_empty());
                }
                (lat, toks)
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut total_tokens = 0usize;
    for h in handles {
        let (lat, toks) = h.join().unwrap();
        all_lat.extend(lat);
        total_tokens += toks;
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::of(&all_lat);

    println!("== E2E results (REAL PJRT compute, over HTTP) ==");
    println!("requests      : {}", all_lat.len());
    println!("decode tokens : {total_tokens}");
    println!("wall time     : {wall:.2}s");
    println!("throughput    : {:.1} req/s, {:.1} tok/s", all_lat.len() as f64 / wall, total_tokens as f64 / wall);
    println!(
        "latency ms    : mean {:.1}  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        s.mean, s.p50, s.p90, s.p99, s.max
    );
    // Engine-side telemetry: the runtime's own prefill/decode counters,
    // the base quantities BENCH_runtime.json tracks (BENCHMARKS.md).
    for (i, r) in replicas.iter().enumerate() {
        if let Ok(rs) = r.stats() {
            println!(
                "replica {i} runtime: prefill {:.0} tok/s, decode {:.0} tok/s ({} decode tokens, {} prefill tokens seeded from pool)",
                rs.prefill_tokens_per_s(),
                rs.decode_tokens_per_s(),
                rs.decode_tokens,
                rs.seeded_prefill_tokens
            );
            println!(
                "replica {i} quant [{}]: {} quantized GEMM calls, {:.1} MiB weight bytes saved",
                r.precision.name(),
                rs.quant_gemm_calls,
                rs.quant_bytes_saved as f64 / (1u64 << 20) as f64
            );
        }
    }
    // Cross-replica KV reuse: what the shared pool did for this run.
    let ps = hook.stats();
    println!(
        "kv pool: {} lookups, hit rate {:.0}% ({} local / {} remote / {} cold blocks), {} dedup-dropped write-backs",
        ps.lookups,
        ps.hit_rate() * 100.0,
        ps.blocks_hit_local,
        ps.blocks_hit_remote,
        ps.blocks_hit_cold,
        ps.inserts_deduped
    );
    // Tiered-cache telemetry (AIBRIX_KV_QUANT / AIBRIX_KV_COLD_MB /
    // AIBRIX_KV_PREFETCH): spill traffic, promotions, end-of-turn
    // prefetch effectiveness, and int8 storage savings.
    let (ram_blocks, cold_blocks) = hook.with_pool(|p| p.tier_blocks());
    println!(
        "kv tiers: {ram_blocks} RAM / {cold_blocks} cold blocks resident, {} spills, {} cold evictions, {} promotions",
        ps.spills, ps.cold_evictions, ps.promotions
    );
    println!(
        "kv prefetch: {} issued, {} hit ({:.0}% hit rate); int8 storage saved {:.1} MiB",
        ps.prefetch_issued,
        ps.prefetch_hits,
        ps.prefetch_hit_rate() * 100.0,
        ps.quant_bytes_saved as f64 / (1u64 << 20) as f64
    );
    println!("\nall layers composed: rust gateway -> engine threads -> TinyLM kernel runtime (AOT manifest)");
    for r in &replicas {
        r.stop();
    }
    Ok(())
}
