//! Multi-turn agent sessions over the distributed KV-cache pool (Figure 5).
//!
//! The Fig-5 story: multi-turn conversations revisit their growing history
//! every turn, and with many sessions the per-engine prefix caches thrash —
//! worse, the router can land a session's next turn on a *different* engine
//! where its KV doesn't exist. The distributed pool makes that KV reusable
//! across engines. This example measures TTFT per turn depth with and
//! without the pool.
//!
//! Run: `cargo run --release --example multi_turn_chat`

use aibrix::cluster::GpuKind;
use aibrix::engine::{EngineConfig, ModelSpec};
use aibrix::gateway::Policy;
use aibrix::harness::{run, HarnessConfig, RunReport};
use aibrix::kvcache::KvPoolConfig;
use aibrix::workload::{ArrivalProcess, ShareGptConfig, ShareGptWorkload};

fn scenario(with_pool: bool) -> RunReport {
    let model = ModelSpec::deepseek_coder_7b();
    let mut ec = EngineConfig::new(GpuKind::A10, model.clone());
    ec.prefix_caching = true;
    let mut wl = ShareGptWorkload::new(ShareGptConfig {
        n_requests: 400,
        turns_mean: 5.0,
        prompt_median: 220.0,
        output_median: 160.0,
        model: model.name.clone(),
        seed: 17,
        ..Default::default()
    });
    run(
        HarnessConfig {
            engines: (0..4).map(|i| (ec.clone(), i as u64)).collect(),
            // Random routing: the adversarial case for engine-local caches —
            // turns hop engines, only the pool can still serve their KV.
            policy: Policy::Random,
            arrival: ArrivalProcess::Poisson { rate: 7.0 },
            kv_pool: with_pool.then(|| {
                KvPoolConfig::new(
                    (0..4u64).map(|i| (i, 64u64 << 30)).collect(),
                    model.kv_bytes_per_token(),
                    16,
                )
            }),
            seed: 17,
            deadline: 0,
            closed_loop_clients: 0,
            view: Default::default(),
            chaos: None,
            recovery: Default::default(),
        },
        &mut wl,
    )
}

fn main() {
    println!("multi-turn chat over 4 engines, random routing (worst case for local caches)\n");
    let without = scenario(false);
    let with = scenario(true);

    // TTFT by cached prefix availability: group by prompt length buckets
    // (longer prompt == deeper turn).
    let bucket = |r: &RunReport, lo: usize, hi: usize| -> (usize, f64) {
        let vals: Vec<f64> = r
            .completions
            .iter()
            .filter(|c| c.prompt_len >= lo && c.prompt_len < hi)
            .map(|c| c.ttft_us() as f64 / 1e3)
            .collect();
        (vals.len(), aibrix::util::mean(&vals))
    };

    println!("{:<26} {:>10} {:>16} {:>16}", "turn depth (prompt len)", "requests", "TTFT no pool", "TTFT with pool");
    for (lo, hi, label) in [
        (0usize, 400usize, "turn 1    (<400 tok)"),
        (400, 1200, "turn 2-3  (400-1200)"),
        (1200, 3000, "turn 4-5  (1200-3000)"),
        (3000, usize::MAX, "turn 6+   (3000+)"),
    ] {
        let (n0, t0) = bucket(&without, lo, hi);
        let (_, t1) = bucket(&with, lo, hi);
        println!("{label:<26} {n0:>10} {t0:>14.0}ms {t1:>14.0}ms");
    }

    let ps = with.pool_stats.as_ref().unwrap();
    println!(
        "\npool: {} lookups, {:.1}% block hit rate ({} local / {} remote), {} deduped write-backs",
        ps.lookups,
        ps.hit_rate() * 100.0,
        ps.blocks_hit_local,
        ps.blocks_hit_remote,
        ps.inserts_deduped
    );
    println!(
        "mean TTFT: {:.0}ms -> {:.0}ms   completion time: {:.0}s -> {:.0}s",
        without.ttft_summary().mean,
        with.ttft_summary().mean,
        without.completion_time_s(),
        with.completion_time_s()
    );
}
