//! Failure drill (Figure 9): inject -> detect -> drain -> recover, end to
//! end, twice over.
//!
//! Act 1 drives the §3.2.8 loop through the serving harness proper: a
//! seeded `ChaosSchedule` kills a replica with requests in flight and
//! drops a KV-pool shard while a Poisson workload runs. The failure
//! injector mirrors each fault into accelerator telemetry, the periodic
//! diagnostics sweep classifies it, the health state machine drains and
//! cordons the dead pod, and every stranded request is re-dispatched with
//! backoff to a healthy replica — zero requests lost, with the detection
//! latency and the full health-transition timeline printed.
//!
//! Act 2 replays the original fleet story at the orchestration layer: the
//! diagnostic verdict cordons the node and the RayClusterFleet controller
//! re-provisions the lost capacity on healthy nodes.
//!
//! Run: `cargo run --release --example failure_drill`

use aibrix::chaos::{ChaosEvent, ChaosFault, ChaosSchedule};
use aibrix::cluster::{ClusterState, GpuKind};
use aibrix::diagnostics::{diagnose, Action, FailureInjector, InjectedFault};
use aibrix::engine::{EngineConfig, EngineSim, ModelSpec};
use aibrix::gateway::Policy;
use aibrix::harness::{run, HarnessConfig};
use aibrix::kvcache::KvPoolConfig;
use aibrix::orchestration::{FleetController, FleetSpec, PlacementStrategy, RayClusterSpec};
use aibrix::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload, Request};

fn main() {
    // ================= Act 1: serving-plane chaos drill =================
    let model = ModelSpec::deepseek_coder_7b();
    let ec = EngineConfig::new(GpuKind::A10, model.clone());
    let n_requests = 120;
    let chaos = ChaosSchedule::new(vec![
        // Off the 2ms sweep grid so the printed detect-to-cordon latency
        // is non-zero (an on-tick fault is cordoned the same instant).
        ChaosEvent { at: 300_500, fault: ChaosFault::ReplicaDeath { pod: 0 } },
        ChaosEvent { at: 600_000, fault: ChaosFault::ShardLoss { node: 1 } },
    ]);
    println!("chaos schedule:");
    for ev in chaos.events() {
        println!("  t={:>7}µs  {:?}", ev.at, ev.fault);
    }

    let mut wl = BirdSqlWorkload::new(BirdSqlConfig {
        n_requests,
        n_schemas: 4,
        schema_tokens_mean: 400,
        question_tokens_mean: 100,
        ..Default::default()
    });
    let report = run(
        HarnessConfig {
            engines: (0..3).map(|i| (ec.clone(), i as u64)).collect(),
            policy: Policy::LeastRequest,
            arrival: ArrivalProcess::Poisson { rate: 120.0 },
            kv_pool: Some(KvPoolConfig::new(
                (0..3u64).map(|i| (i, 64u64 << 30)).collect(),
                model.kv_bytes_per_token(),
                16,
            )),
            seed: 9,
            deadline: 0,
            closed_loop_clients: 0,
            view: Default::default(),
            chaos: Some(chaos),
            recovery: Default::default(),
        },
        &mut wl,
    );

    println!("\nhealth timeline:");
    for (t, pod, state) in &report.health_transitions {
        println!("  t={t:>7}µs  pod {pod} -> {state:?}");
    }
    println!(
        "\n{} completed, {} typed rejections, {} stranded requests recovered in {} re-dispatch attempts",
        report.completions.len(),
        report.rejections.len(),
        report.recovered,
        report.retries,
    );
    if let Some(d) = report.detect_to_cordon_us {
        println!("detect-to-cordon: {d}µs (fault fire -> pod Cordoned)");
    }
    if let Some(p) = &report.pool_stats {
        println!(
            "pool: {} shard dropped ({} blocks), consumers degraded to recompute",
            p.shards_dropped, p.blocks_dropped
        );
    }

    // The drill's contract — the same invariants the chaos proptests and
    // the chaos_e2e bench gate on.
    assert_eq!(
        report.completions.len() + report.rejections.len(),
        n_requests,
        "every request must end as a completion or a typed rejection"
    );
    assert!(report.recovered > 0, "the dead replica must strand work that recovers");
    assert!(
        report.detect_to_cordon_us.is_some_and(|d| d > 0),
        "the dead replica must be detected and cordoned"
    );
    let p = report.pool_stats.as_ref().unwrap();
    assert_eq!(p.shards_dropped, 1);

    // ================= Act 2: fleet re-provision drill ==================
    // ---- cluster: 3 nodes x 2 A100s, one 2-GPU inference cluster --------
    let mut state = ClusterState::new();
    for _ in 0..3 {
        state.add_node(GpuKind::A100, 2, 256);
    }
    let mut fleet = FleetController::new(FleetSpec {
        name: "dsr1".into(),
        replicas: 2,
        cluster: RayClusterSpec {
            model: "deepseek-r1-sim".into(),
            gpu: GpuKind::A100,
            workers: 1,
            placement: PlacementStrategy::Pack,
        },
        generation: 1,
        max_unavailable: 1,
    });
    fleet.reconcile(0, &mut state);
    let pending: Vec<u64> = state.pods.keys().copied().collect();
    for p in pending {
        state.mark_ready(1, p);
    }
    fleet.reconcile(1, &mut state);
    println!(
        "\nt=1s   fleet up: {} RayClusters ready, {} pods",
        fleet.ready_clusters(),
        state.pods.len()
    );

    // ---- engine serving traffic on node 0 -------------------------------
    let mut engine = EngineSim::new(0, 0, EngineConfig::new(GpuKind::A100, ModelSpec::llama_8b()));
    for i in 0..12 {
        engine.enqueue(Request {
            id: i,
            session: 0,
            tokens: vec![5; 400],
            output_len: 32,
            arrival: 0,
            model: "llama-8b".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
            end_session: false,
            deadline: None,
            tier: Default::default(),
        });
    }
    let mut now = 1_000_000u64;
    for _ in 0..3 {
        if let Some(dt) = engine.step(now, None) {
            now += dt;
        }
    }
    println!("t=2s   engine serving: {} in flight", 12 - engine.completions.len());

    // ---- inject a fault on node 0, GPU 0 ---------------------------------
    let mut injector = FailureInjector::new();
    injector.inject(0, 0, InjectedFault::EccUncorrectable);
    println!("t=3s   MOCKUP: injected uncorrectable ECC fault on node 0 / gpu 0");

    // ---- diagnostics sweep ----------------------------------------------
    let mut cordoned = false;
    for node in 0..3u64 {
        for gpu in 0..2u32 {
            let telemetry = injector.sample(node, gpu, now);
            for d in diagnose(&telemetry) {
                println!(
                    "t=4s   DIAGNOSE node {} gpu {}: {:?} ({:?}) -> {:?}   [{}]",
                    node, gpu, d.fault, d.severity, d.action, d.detail
                );
                if d.action == Action::DrainAndCordon {
                    // Drain the engine, fail the node.
                    let requeued = engine.fail_and_drain();
                    println!(
                        "t=5s   CORDON node {}: drained {} in-flight requests for re-route",
                        node,
                        requeued.len()
                    );
                    let failed_pods = state.fail_node(now, node);
                    println!("t=5s   node {} down: {} pods failed", node, failed_pods.len());
                    cordoned = true;
                }
            }
        }
    }
    assert!(cordoned, "diagnostic must have fired");

    // ---- recovery: controller re-provisions on healthy nodes ------------
    for pass in 0..3 {
        fleet.reconcile(now + pass, &mut state);
        let pending: Vec<u64> = state
            .pods
            .values()
            .filter(|p| p.phase == aibrix::cluster::PodPhase::Pending)
            .map(|p| p.id)
            .collect();
        for p in pending {
            state.mark_ready(now + pass + 1, p);
        }
    }
    fleet.reconcile(now + 10, &mut state);
    println!(
        "t=6s   RECOVERED: {} RayClusters ready again (on healthy nodes only)",
        fleet.ready_clusters()
    );
    assert_eq!(fleet.ready_clusters(), 2);
    for c in fleet.clusters() {
        for pod in c.pods() {
            let node = state.pods[&pod].node.unwrap();
            assert_ne!(node, 0, "no pod may sit on the cordoned node");
        }
    }

    // ---- clear the fault, node returns ----------------------------------
    injector.clear(0, 0);
    state.recover_node(now + 20, 0);
    engine.recover();
    println!("t=9s   fault cleared, node 0 uncordoned, engine back in rotation");
    println!("\ndrill complete: inject -> diagnose -> cordon -> re-provision -> recover");
}
