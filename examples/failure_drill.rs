//! Failure drill (Figure 9): mockup -> detect -> recover, end to end.
//!
//! The §3.2.8 loop: the failure-mockup tool injects a GPU fault, the
//! diagnostic engine classifies it and recommends an action, the cluster
//! cordons the node, the RayClusterFleet controller re-provisions the lost
//! capacity elsewhere, and serving resumes — with the whole timeline
//! printed. Also demonstrates engine-level drain/re-route of in-flight
//! requests.
//!
//! Run: `cargo run --release --example failure_drill`

use aibrix::cluster::{ClusterState, GpuKind};
use aibrix::diagnostics::{diagnose, Action, FailureInjector, InjectedFault};
use aibrix::engine::{EngineConfig, EngineSim, ModelSpec};
use aibrix::orchestration::{
    FleetController, FleetSpec, PlacementStrategy, RayClusterSpec,
};
use aibrix::workload::Request;

fn main() {
    // ---- cluster: 3 nodes x 2 A100s, one 2-GPU inference cluster --------
    let mut state = ClusterState::new();
    for _ in 0..3 {
        state.add_node(GpuKind::A100, 2, 256);
    }
    let mut fleet = FleetController::new(FleetSpec {
        name: "dsr1".into(),
        replicas: 2,
        cluster: RayClusterSpec {
            model: "deepseek-r1-sim".into(),
            gpu: GpuKind::A100,
            workers: 1,
            placement: PlacementStrategy::Pack,
        },
        generation: 1,
        max_unavailable: 1,
    });
    fleet.reconcile(0, &mut state);
    let pending: Vec<u64> = state.pods.keys().copied().collect();
    for p in pending {
        state.mark_ready(1, p);
    }
    fleet.reconcile(1, &mut state);
    println!(
        "t=1s   fleet up: {} RayClusters ready, {} pods",
        fleet.ready_clusters(),
        state.pods.len()
    );

    // ---- engine serving traffic on node 0 -------------------------------
    let mut engine = EngineSim::new(0, 0, EngineConfig::new(GpuKind::A100, ModelSpec::llama_8b()));
    for i in 0..12 {
        engine.enqueue(Request {
            id: i,
            session: 0,
            tokens: vec![5; 400],
            output_len: 32,
            arrival: 0,
            model: "llama-8b".into(),
            adapter: None,
            user: 0,
            shared_prefix_len: 0,
        });
    }
    let mut now = 1_000_000u64;
    for _ in 0..3 {
        if let Some(dt) = engine.step(now, None) {
            now += dt;
        }
    }
    println!("t=2s   engine serving: {} in flight", 12 - engine.completions.len());

    // ---- inject a fault on node 0, GPU 0 ---------------------------------
    let mut injector = FailureInjector::new();
    injector.inject(0, 0, InjectedFault::EccUncorrectable);
    println!("t=3s   MOCKUP: injected uncorrectable ECC fault on node 0 / gpu 0");

    // ---- diagnostics sweep ----------------------------------------------
    let mut cordoned = false;
    for node in 0..3u64 {
        for gpu in 0..2u32 {
            let telemetry = injector.sample(node, gpu, now);
            for d in diagnose(&telemetry) {
                println!(
                    "t=4s   DIAGNOSE node {} gpu {}: {:?} ({:?}) -> {:?}   [{}]",
                    node, gpu, d.fault, d.severity, d.action, d.detail
                );
                if d.action == Action::DrainAndCordon {
                    // Drain the engine, fail the node.
                    let requeued = engine.fail_and_drain();
                    println!(
                        "t=5s   CORDON node {}: drained {} in-flight requests for re-route",
                        node,
                        requeued.len()
                    );
                    let failed_pods = state.fail_node(now, node);
                    println!("t=5s   node {} down: {} pods failed", node, failed_pods.len());
                    cordoned = true;
                }
            }
        }
    }
    assert!(cordoned, "diagnostic must have fired");

    // ---- recovery: controller re-provisions on healthy nodes ------------
    for pass in 0..3 {
        fleet.reconcile(now + pass, &mut state);
        let pending: Vec<u64> = state
            .pods
            .values()
            .filter(|p| p.phase == aibrix::cluster::PodPhase::Pending)
            .map(|p| p.id)
            .collect();
        for p in pending {
            state.mark_ready(now + pass + 1, p);
        }
    }
    fleet.reconcile(now + 10, &mut state);
    println!(
        "t=6s   RECOVERED: {} RayClusters ready again (on healthy nodes only)",
        fleet.ready_clusters()
    );
    assert_eq!(fleet.ready_clusters(), 2);
    for c in fleet.clusters() {
        for pod in c.pods() {
            let node = state.pods[&pod].node.unwrap();
            assert_ne!(node, 0, "no pod may sit on the cordoned node");
        }
    }

    // ---- clear the fault, node returns ----------------------------------
    injector.clear(0, 0);
    state.recover_node(now + 20, 0);
    engine.recover();
    println!("t=9s   fault cleared, node 0 uncordoned, engine back in rotation");
    println!("\ndrill complete: inject -> diagnose -> cordon -> re-provision -> recover");
}
