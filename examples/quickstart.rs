//! Quickstart: the 60-second AIBrix tour.
//!
//! Spins up a 3-pod simulated cluster serving deepseek-coder-7b, pushes a
//! small prefix-heavy workload through the gateway under two routing
//! policies, and prints the latency difference — the core loop every other
//! example builds on.
//!
//! Run: `cargo run --release --example quickstart`

use aibrix::cluster::GpuKind;
use aibrix::engine::{EngineConfig, ModelSpec};
use aibrix::gateway::Policy;
use aibrix::harness::{run, HarnessConfig};
use aibrix::workload::{ArrivalProcess, BirdSqlConfig, BirdSqlWorkload};

fn main() {
    println!("AIBrix quickstart: 3 pods, 120 text-to-SQL requests\n");

    for policy in [Policy::Random, Policy::PrefixCacheAware { threshold: 0.3 }] {
        let mut ec = EngineConfig::new(GpuKind::A10, ModelSpec::deepseek_coder_7b());
        ec.prefix_caching = true;
        let mut workload = BirdSqlWorkload::new(BirdSqlConfig {
            n_requests: 120,
            n_schemas: 6,
            schema_tokens_mean: 800,
            question_tokens_mean: 150,
            ..Default::default()
        });
        let report = run(
            HarnessConfig {
                engines: (0..3).map(|i| (ec.clone(), i as u64)).collect(),
                policy,
                arrival: ArrivalProcess::Poisson { rate: 6.0 },
                kv_pool: None,
                seed: 1,
                deadline: 0,
                closed_loop_clients: 0,
                view: Default::default(),
                chaos: None,
                recovery: Default::default(),
            },
            &mut workload,
        );
        let lat = report.latency_summary();
        let ttft = report.ttft_summary();
        println!(
            "policy {:<20} completed {:>3}  mean latency {:>7.0}ms  p99 {:>7.0}ms  mean TTFT {:>6.0}ms  prefix hit {:>4.1}%",
            policy.name(),
            report.completions.len(),
            lat.mean,
            lat.p99,
            ttft.mean,
            report.prefix_hit_rates.iter().sum::<f64>() / 3.0 * 100.0,
        );
    }

    println!("\nprefix-cache-aware routing concentrates shared schemas onto warm pods;");
    println!("see `cargo bench --bench fig3_routing` for the full six-policy comparison.");
}
