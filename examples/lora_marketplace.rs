//! High-density LoRA management (Figure 2): a "marketplace" of 64 fine-
//! tunes served by 4 base-model pods.
//!
//! Shows the §3.2.1 pipeline: dynamic adapter registration -> controller
//! placement (weight-balanced bin packing) -> EndpointSlice-style discovery
//! -> LoRA-affinity routing, and measures how affinity routing avoids
//! adapter reload penalties under a Zipf-skewed adapter workload.
//!
//! Run: `cargo run --release --example lora_marketplace`

use aibrix::cluster::GpuKind;
use aibrix::engine::{EngineConfig, ModelSpec};
use aibrix::gateway::Policy;
use aibrix::harness::{run, HarnessConfig};
use aibrix::lora::{AdapterSpec, LoraController, PodInfo};
use aibrix::workload::{ArrivalProcess, ShareGptConfig, ShareGptWorkload};

fn main() {
    // --- control plane: register 64 adapters against 4 pods -------------
    let mut ctl = LoraController::new(24);
    for i in 0..64 {
        let mut spec = AdapterSpec::new(&format!("lora-{i}"), "llama-8b");
        spec.weight = 1.0 / (i as f64 + 1.0); // Zipf-ish popularity
        spec.min_replicas = if i < 4 { 2 } else { 1 }; // hot adapters replicated
        ctl.register(spec);
    }
    let pods: Vec<PodInfo> = (0..4)
        .map(|id| PodInfo { id, base_model: "llama-8b".into(), ready: true })
        .collect();
    let actions = ctl.reconcile(&pods);
    println!(
        "registered 64 adapters -> {} placements across 4 pods ({} loads issued)",
        ctl.total_placements(),
        actions.len()
    );
    for p in 0..4 {
        let on = ctl.adapters_on(p);
        println!("  pod {p}: {} adapters (e.g. {:?})", on.len(), &on[..on.len().min(4)]);
    }
    println!(
        "discovery: lora-0 -> pods {:?}, lora-63 -> pods {:?}\n",
        ctl.endpoints("lora-0"),
        ctl.endpoints("lora-63")
    );

    // --- data plane: affinity routing vs random ------------------------
    let serve = |affinity: bool| {
        let mut ec = EngineConfig::new(GpuKind::A10, ModelSpec::llama_8b());
        ec.max_loras = 24;
        let mut wl = ShareGptWorkload::new(ShareGptConfig {
            n_requests: 500,
            adapter_fraction: 0.8,
            n_adapters: 64,
            turns_mean: 1.2,
            prompt_median: 150.0,
            output_median: 60.0,
            ..Default::default()
        });
        let cfg = HarnessConfig {
            engines: (0..4).map(|i| (ec.clone(), i as u64)).collect(),
            policy: Policy::LeastRequest,
            arrival: ArrivalProcess::Poisson { rate: 10.0 },
            kv_pool: None,
            seed: 9,
            deadline: 0,
            closed_loop_clients: 0,
            view: Default::default(),
            chaos: None,
            recovery: Default::default(),
        };
        aibrix::harness::run_with_router_config(cfg, &mut wl, affinity)
    };

    let plain = serve(false);
    let affine = serve(true);
    println!("LoRA-aware routing vs adapter-blind (80% of 500 requests carry one of 64 adapters):");
    println!(
        "  blind   : mean TTFT {:>6.0}ms  p99 latency {:>7.0}ms",
        plain.ttft_summary().mean,
        plain.latency_summary().p99
    );
    println!(
        "  affinity: mean TTFT {:>6.0}ms  p99 latency {:>7.0}ms",
        affine.ttft_summary().mean,
        affine.latency_summary().p99
    );
    println!("\naffinity keeps hot adapters resident, avoiding the 200ms reload on miss.");
}
