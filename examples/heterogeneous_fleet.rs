//! The GPU optimizer driving a heterogeneous fleet (Figure 8).
//!
//! Walks the full §3.2.7 pipeline interactively: profile GPUs -> watch the
//! load monitor build a demand picture -> solve the ILP -> compare the
//! planned fleet against naive single-GPU plans as demand shifts from
//! small-request to long-context traffic.
//!
//! Run: `cargo run --release --example heterogeneous_fleet`

use aibrix::cluster::{GpuKind, GpuSpec};
use aibrix::engine::ModelSpec;
use aibrix::optimizer::ilp::{solve, IlpProblem};
use aibrix::optimizer::loadmonitor::LoadMonitor;
use aibrix::optimizer::profiles::{ProfileTable, Slo};

fn plan(profiles: &ProfileTable, gpus: &[GpuKind], monitor: &LoadMonitor) -> (Vec<(GpuKind, usize)>, f64) {
    let problem = IlpProblem::build(profiles, gpus, &monitor.demand(), 64);
    let sol = solve(&problem);
    let counts: Vec<(GpuKind, usize)> = gpus
        .iter()
        .zip(&sol.counts)
        .map(|(&g, &n)| (g, n))
        .filter(|&(_, n)| n > 0)
        .collect();
    (counts, sol.cost_per_hour)
}

fn show(label: &str, counts: &[(GpuKind, usize)], cost: f64) {
    let fleet = counts
        .iter()
        .map(|(g, n)| format!("{n}x{}", g.name()))
        .collect::<Vec<_>>()
        .join(" + ");
    println!("  {label:<24} {fleet:<18} ${cost:.2}/hr");
}

fn main() {
    let model = ModelSpec::deepseek_coder_7b();
    let gpus = [GpuKind::A10, GpuKind::L20];
    let profiles = ProfileTable::build(&model, &gpus, Slo::default());
    println!(
        "profiled {} for {:?} under SLO (TTFT {:.0}ms, ITL {:.0}ms)\n",
        model.name,
        gpus.iter().map(|g| g.name()).collect::<Vec<_>>(),
        Slo::default().ttft_ms,
        Slo::default().itl_ms
    );

    let phases: [(&str, usize, usize, usize); 3] = [
        ("phase 1: short queries", 120, 50, 80),
        ("phase 2: mixed", 400, 150, 60),
        ("phase 3: long contexts", 1500, 400, 40),
    ];

    for (label, input, output, rps10) in phases {
        let mut monitor = LoadMonitor::new();
        for _ in 0..rps10 {
            monitor.record(input, output, 1.0);
        }
        // A constant background of the other shape keeps it a true mix.
        for _ in 0..20 {
            monitor.record(800, 200, 1.0);
        }
        println!("{label} (~{input} in / {output} out @ {:.0} req/s + background):", rps10 as f64 / 10.0);
        let (het, het_cost) = plan(&profiles, &gpus, &monitor);
        show("optimizer (A10+L20)", &het, het_cost);
        for g in gpus {
            let (homo, cost) = plan(&profiles, &[g], &monitor);
            show(&format!("{} only", g.name()), &homo, cost);
        }
        let cheapest_homo = gpus
            .iter()
            .map(|&g| plan(&profiles, &[g], &monitor).1)
            .fold(f64::INFINITY, f64::min);
        println!(
            "  -> heterogeneous saves {:+.1}% vs best homogeneous\n",
            (het_cost - cheapest_homo) / cheapest_homo * 100.0
        );
    }

    println!("price sheet:");
    for g in [GpuKind::A10, GpuKind::L20, GpuKind::V100] {
        let s = GpuSpec::of(g);
        println!(
            "  {:<5} {:>6.1} TFLOPS  {:>6.0} GB/s  {:>4.0} GiB  ${:.2}/hr",
            g.name(),
            s.fp16_tflops,
            s.hbm_gbps,
            s.vram_gib,
            s.dollars_per_hour
        );
    }
}
